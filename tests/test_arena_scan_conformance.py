"""Arena-scan conformance matrix — ONE grid proving every scan family.

All four kernel families (filtered_topk, grouped_topk, ivf_probe,
hybrid_score) are thin wrappers over `repro.kernels.arena_scan`; this file
is the framework's acceptance contract (ISSUE 7):

  * ENGINE CONFORMANCE: for every (family x shape bucket x page size x
    group count) cell, the dense jnp oracle, the streaming jnp scan, the
    Pallas kernel body (interpret mode on CPU), and BOTH paged variants
    (scan tiled at the page, kernel on double-buffered DMA) return
    bit-equal scores AND slots. The grid includes arenas larger than one
    page (N > page_rows -> multi-page DMA loop), N not a tile multiple
    (dead-row padding path), G at pow2 pad boundaries (3 -> blocker lane,
    4 -> exact), and the historical wsum FMA-divergence shapes
    (5,700,48) / (8,1024,128) at qt in {4, 16} that ISSUE 7 turned green;
  * LEAKAGE IMPOSSIBILITY holds in every cell: a returned slot always
    satisfies ITS group's predicate under an independent numpy oracle —
    the multi-tenant isolation claim, per family and per regime;
  * AUDIT CONFORMANCE: `rows_scanned` / `terms_scanned` report the same
    arena traffic for paged and resident launches (paging changes the DMA
    schedule, never the rows scored), and paged/resident launches occupy
    DISTINCT compiled-shape slots (different grid -> different program);
  * PLAN CONFORMANCE: a planner-stamped paged plan (PlannerConfig
    .paged_min_rows) executes bit-identical to its resident twin through
    `execute_plans`, increments `ExecStats.paged_scans`, and renders the
    "paging:" EXPLAIN line.

The per-family regression grids (test_kernels / test_grouped_topk /
test_hybrid / test_ivf_engine) stay as deep per-family coverage; this
matrix is the single cross-family gate CI runs on every push.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import executor as executor_mod
from repro.api.executor import (CompiledShapes, ExecStats, _finish_hot,
                                _launch_hybrid, run_grouped_fused)
from repro.api.plan import LogicalPlan
from repro.api.planner import PlannerConfig, compile_plan
from repro.core.query import (Predicate, stack_predicates, unified_query,
                              unified_query_ref)
from repro.kernels.arena_scan.ops import _pad_axis0, pad_d128
from repro.kernels.grouped_topk.ops import _packed_meta, grouped_topk
from repro.kernels.grouped_topk.ref import grouped_topk_ref
from repro.kernels.hybrid_score.ops import hybrid_score
from repro.kernels.hybrid_score.ref import hybrid_score_ref
from repro.kernels.ivf_probe.ivf_probe import ivf_probe_pallas
from repro.kernels.ivf_probe.ref import ivf_probe_ref, ivf_probe_scan_ref

pytestmark = [pytest.mark.kernels, pytest.mark.slow]

W_DENSE, W_LEX = 0.8, 1.7    # the historical FMA-divergence weights
V, T_LANES = 64, 6


# ---------------------------------------------------------------------------
# shared fixtures: one arena schema serves every family
# ---------------------------------------------------------------------------

def _arena(rng, n, d, n_tenants=5):
    terms = rng.integers(-1, V, (n, T_LANES)).astype(np.int32)
    lexnorm = np.where(terms >= 0,
                       (rng.random((n, T_LANES)) * 2).astype(np.float32),
                       0.0).astype(np.float32)
    return {
        "emb": jnp.asarray(rng.standard_normal((n, d)).astype(np.float32)),
        "tenant": jnp.asarray(rng.integers(-1, n_tenants, n, dtype=np.int32)),
        "updated_at": jnp.asarray(rng.integers(0, 1000, n, dtype=np.int32)),
        "category": jnp.asarray(rng.integers(0, 8, n, dtype=np.int32)),
        "acl": jnp.asarray(rng.integers(1, 16, n, dtype=np.int64)
                           .astype(np.uint32)),
        "terms": jnp.asarray(terms),
        "lexnorm": jnp.asarray(lexnorm),
        "idf": jnp.asarray((rng.random(V) * 5).astype(np.float32)),
    }


def _oracle_mask(store, pred: Predicate) -> np.ndarray:
    """Independent numpy WHERE clause (no jax) for leakage assertions."""
    tenant = np.asarray(store["tenant"])
    ok = tenant >= 0
    if pred.tenant != -2:
        ok &= tenant == pred.tenant
    ok &= np.asarray(store["updated_at"]) >= pred.min_ts
    ok &= (np.uint32(pred.cat_mask)
           >> np.asarray(store["category"]).astype(np.uint32)) & 1 != 0
    ok &= (np.asarray(store["acl"]) & np.uint32(pred.acl_bits)) != 0
    return ok


def _assert_no_leak(store, preds, gids, slots):
    """Every returned slot must satisfy ITS group's predicate."""
    masks = [_oracle_mask(store, p) for p in preds]
    slots = np.asarray(slots)
    for b in range(slots.shape[0]):
        real = slots[b][slots[b] >= 0]
        assert masks[int(gids[b])][real].all(), (
            f"row {b} (group {int(gids[b])}) leaked slots "
            f"{real[~masks[int(gids[b])][real]]}")


def _assert_all_equal(outs: dict):
    """Bit-equality across every engine lane, named for the failure."""
    names = list(outs)
    s0, i0 = (np.asarray(a) for a in outs[names[0]])
    for name in names[1:]:
        s, i = (np.asarray(a) for a in outs[name])
        assert (s == s0).all(), f"{name} scores != {names[0]}"
        assert (i == i0).all(), f"{name} slots != {names[0]}"


# ---------------------------------------------------------------------------
# per-family engine lanes: oracle / scan / kernel x resident / paged
# ---------------------------------------------------------------------------

def _lanes_filtered(rng, store, B, N, D, k, G, qt, page):
    """Single-predicate family. The bit oracle is the G=1 arena-scan dense
    oracle; the core `unified_query_ref` is a DIFFERENT XLA program (its
    own matmul + mask fusion) and is held to allclose + same winner set,
    not bits — the framework's bit contract covers its own engines."""
    pred = Predicate(tenant=1, min_ts=100)
    q = rng.standard_normal((B, D)).astype(np.float32)
    meta = _packed_meta(store["tenant"], store["updated_at"],
                        store["category"], store["acl"])
    outs = {
        "oracle": grouped_topk_ref(jnp.asarray(q), store["emb"], meta,
                                   jnp.zeros(B, jnp.int32),
                                   pred.as_array()[None, :], k),
        "scan": unified_query(store, jnp.asarray(q), pred, k, engine="ref",
                              page_rows=N),   # one tile = classic scan
        "kernel": unified_query(store, jnp.asarray(q), pred, k,
                                engine="pallas"),
    }
    if page is not None:
        outs["scan-paged"] = unified_query(store, jnp.asarray(q), pred, k,
                                           engine="ref", page_rows=page)
        outs["kernel-paged"] = unified_query(store, jnp.asarray(q), pred, k,
                                             engine="pallas", page_rows=page)
    s_core, i_core = unified_query_ref(store, jnp.asarray(q),
                                       pred.as_array(), k)
    s_o, i_o = outs["oracle"]
    assert np.allclose(np.asarray(s_core), np.asarray(s_o), atol=1e-5)
    assert (np.asarray(i_core) == np.asarray(i_o)).all()
    return outs, [pred], np.zeros(B, np.int32)


def _lanes_grouped(rng, store, B, N, D, k, G, qt, page):
    q = rng.standard_normal((B, D)).astype(np.float32)
    gids = rng.integers(0, G, B).astype(np.int32)
    preds = [Predicate(tenant=i % 3, min_ts=100) for i in range(G)]
    pa = stack_predicates(preds)
    meta = _packed_meta(store["tenant"], store["updated_at"],
                        store["category"], store["acl"])

    def call(**kw):
        return grouped_topk(q, store["emb"], store["tenant"],
                            store["updated_at"], store["category"],
                            store["acl"], gids, pa, k, **kw)

    outs = {
        "oracle": grouped_topk_ref(jnp.asarray(q), store["emb"], meta,
                                   jnp.asarray(gids), pa, k),
        "scan": call(use_kernel=False),
        "kernel": call(use_kernel=True, interpret=True),
    }
    if page is not None:
        outs["scan-paged"] = call(use_kernel=False, page_rows=page)
        outs["kernel-paged"] = call(use_kernel=True, interpret=True,
                                    page_rows=page)
    return outs, preds, gids


def _lanes_ivf(rng, store, B, N, D, k, G, qt, page):
    """ivf probes a gathered candidate set with a slot lane; ~1/8 of the
    candidates are dead member-table padding (slot -1), exercising the
    dead-slot path in every regime. N here is the candidate count P."""
    pred = Predicate(tenant=1, min_ts=100)
    q = rng.standard_normal((B, D)).astype(np.float32)
    slots = rng.permutation(4 * N)[:N].astype(np.int32)
    dead = rng.random(N) < 0.125
    slots[dead] = -1
    meta = np.stack([np.asarray(store["tenant"]),
                     np.asarray(store["updated_at"]),
                     np.asarray(store["category"]),
                     np.asarray(store["acl"]).view(np.int32),
                     slots], axis=1).astype(np.int32)
    meta[dead] = [-1, 0, 0, 0, -1]
    cand_emb = np.asarray(store["emb"]).copy()
    cand_emb[dead] = 0.0
    cand_emb, meta = jnp.asarray(cand_emb), jnp.asarray(meta)
    pa = pred.as_array()

    qp, embp = pad_d128(jnp.asarray(q), cand_emb)
    qp = _pad_axis0(qp, 8, 0)

    def kernel(**kw):
        s, i = ivf_probe_pallas(qp, embp, meta, pa, k, blk_b=8,
                                interpret=True, **kw)
        return s[:B], i[:B]

    outs = {
        "oracle": ivf_probe_ref(jnp.asarray(q), cand_emb, meta, pa, k),
        "scan": ivf_probe_scan_ref(jnp.asarray(q), cand_emb, meta, pa, k,
                                   blk_p=N),
        "kernel": kernel(blk_p=256),
    }
    if page is not None:
        outs["scan-paged"] = ivf_probe_scan_ref(jnp.asarray(q), cand_emb,
                                                meta, pa, k, blk_p=page)
        outs["kernel-paged"] = kernel(blk_p=256, page_rows=page)

    # slot-lane leakage: returned ARENA slots must come from live candidates
    # that pass the predicate
    cand_ok = _oracle_mask(store, pred) & ~dead
    legal = set(slots[cand_ok].tolist())
    for name, (_, i) in outs.items():
        for slot in np.asarray(i).ravel():
            assert slot == -1 or int(slot) in legal, (
                f"{name} returned slot {slot} outside the qualifying "
                f"candidate set")
    return outs, None, None


def _lanes_hybrid(mode):
    def lanes(rng, store, B, N, D, k, G, qt, page):
        q = rng.standard_normal((B, D)).astype(np.float32)
        qterms = rng.integers(-1, V, (B, qt)).astype(np.int32)
        qterms[:, 0] = rng.integers(0, V, B)     # at least one real term
        gids = rng.integers(0, G, B).astype(np.int32)
        preds = [Predicate(tenant=i % 3, min_ts=100) for i in range(G)]
        pa = stack_predicates(preds)
        kw = dict(mode=mode, w_dense=W_DENSE, w_lex=W_LEX)

        def call(**extra):
            return hybrid_score(q, store["emb"], store["tenant"],
                                store["updated_at"], store["category"],
                                store["acl"], store["terms"],
                                store["lexnorm"], store["idf"], gids, pa,
                                qterms, k, **kw, **extra)

        meta = _packed_meta(store["tenant"], store["updated_at"],
                            store["category"], store["acl"])
        qidf = np.where(qterms >= 0,
                        np.asarray(store["idf"])[np.clip(qterms, 0, None)],
                        0.0).astype(np.float32)
        outs = {
            "oracle": hybrid_score_ref(jnp.asarray(q), store["emb"], meta,
                                       store["terms"], store["lexnorm"],
                                       jnp.asarray(gids), pa,
                                       jnp.asarray(qterms),
                                       jnp.asarray(qidf), k, **kw),
            "scan": call(use_kernel=False),
            "kernel": call(use_kernel=True, interpret=True),
        }
        if page is not None:
            outs["scan-paged"] = call(use_kernel=False, page_rows=page)
            outs["kernel-paged"] = call(use_kernel=True, interpret=True,
                                        page_rows=page)
        return outs, preds, gids
    return lanes


FAMILIES = {
    "filtered": _lanes_filtered,
    "grouped": _lanes_grouped,
    "ivf": _lanes_ivf,
    "hybrid-wsum": _lanes_hybrid("wsum"),
    "hybrid-rrf": _lanes_hybrid("rrf"),
}

# (family, B, N, D, k, G, qt, page_rows) — page_rows=None pins the resident
# regime only; page_rows < N exercises a genuine multi-page DMA loop.
CASES = [
    # --- filtered (G=1 by construction) ---
    ("filtered", 1, 64, 8, 4, 1, 0, None),
    ("filtered", 5, 700, 48, 8, 1, 0, 256),     # 3 pages, N % page != 0
    ("filtered", 8, 1024, 128, 10, 1, 0, 512),  # 2 pages, exact multiple
    ("filtered", 3, 513, 64, 8, 1, 0, 128),     # 5 pages, odd N
    # --- grouped (G spans the pow2 pad boundary) ---
    ("grouped", 1, 64, 8, 4, 1, 0, None),
    ("grouped", 8, 1000, 96, 10, 3, 0, 256),    # G=3 -> blocker-padded to 4
    ("grouped", 3, 513, 64, 8, 4, 0, 128),      # G=4 -> exact pow2
    ("grouped", 16, 2048, 128, 5, 7, 0, 512),
    # --- ivf (slot-lane candidates incl. dead member padding) ---
    ("ivf", 8, 512, 64, 8, 1, 0, None),
    ("ivf", 5, 512, 48, 8, 1, 0, 128),          # 4 pages
    ("ivf", 3, 768, 32, 6, 1, 0, 256),          # 3 pages
    # --- hybrid wsum (incl. the historical FMA-divergence shapes) ---
    ("hybrid-wsum", 1, 64, 8, 4, 1, 1, None),
    ("hybrid-wsum", 5, 700, 48, 8, 3, 4, 256),
    ("hybrid-wsum", 8, 1024, 128, 10, 3, 16, 512),
    ("hybrid-wsum", 3, 513, 64, 8, 4, 4, 128),
    # --- hybrid rrf ---
    ("hybrid-rrf", 1, 64, 8, 4, 1, 1, None),
    ("hybrid-rrf", 5, 700, 48, 8, 3, 4, 256),
    ("hybrid-rrf", 8, 1024, 128, 10, 3, 16, 512),
]

IDS = [f"{f}-B{B}-N{N}-D{D}-k{k}-G{G}-qt{qt}-pg{pg}"
       for f, B, N, D, k, G, qt, pg in CASES]


@pytest.mark.parametrize("family,B,N,D,k,G,qt,page", CASES, ids=IDS)
def test_conformance_matrix(family, B, N, D, k, G, qt, page, rng):
    """Every engine lane of every family returns the same bits, and no lane
    can leak a row its group's predicate rejects."""
    store = _arena(rng, N, D)
    outs, preds, gids = FAMILIES[family](rng, store, B, N, D, k, G, qt, page)
    if page is not None:
        assert N > page, "paged cells must cover arena > 1 page"
        assert {"scan-paged", "kernel-paged"} <= outs.keys()
    _assert_all_equal(outs)
    if preds is not None:   # ivf asserts its slot-lane leakage inline
        for name, (_, slots) in outs.items():
            _assert_no_leak(store, preds, gids, slots)


# ---------------------------------------------------------------------------
# audit conformance: paging changes the DMA schedule, never the audit trail
# ---------------------------------------------------------------------------

def test_rows_scanned_audit_paged_equals_resident(rng):
    """A paged fused grouped scan reports the same `rows_scanned` as its
    resident twin (the arena N, ONCE — not per page, not per group), returns
    the same bits, and occupies a DISTINCT compiled-shape slot."""
    N, D, B, G, k = 1000, 32, 9, 3, 7
    store = _arena(rng, N, D)
    q = rng.standard_normal((B, D)).astype(np.float32)
    uniq = [Predicate(tenant=i % 3, min_ts=100) for i in range(G)]
    preds = [uniq[i % G] for i in range(B)]

    shapes = CompiledShapes()
    st_res, st_pg = ExecStats(), ExecStats()
    s_r, i_r, _ = run_grouped_fused(dict(store), q, preds, k, stats=st_res,
                                    shapes=shapes)
    s_p, i_p, _ = run_grouped_fused(dict(store), q, preds, k, stats=st_pg,
                                    shapes=shapes, page_rows=256)
    assert (np.asarray(s_r) == np.asarray(s_p)).all()
    assert (np.asarray(i_r) == np.asarray(i_p)).all()
    assert st_res.rows_scanned == N
    assert st_pg.rows_scanned == N, "paging must not inflate the row audit"
    assert shapes.misses == 2, (
        "paged and resident launches compile different programs and must "
        "key separate compiled-shape slots")


def test_terms_scanned_audit_paged_equals_resident(rng):
    """The hybrid lexical-bandwidth audit (`terms_scanned` = N * doc term
    lanes) is regime-independent, and the paged launch returns the same
    bits through the executor's launch/finish path."""
    N, D, B, G, k, qt = 768, 16, 6, 3, 5, 4
    store = _arena(rng, N, D)
    lex = {"terms": store["terms"], "lexnorm": store["lexnorm"],
           "idf": store["idf"]}
    q = rng.standard_normal((B, D)).astype(np.float32)
    qterms = rng.integers(0, V, (B, qt)).astype(np.int32)
    gids = np.asarray([i % G for i in range(B)], np.int32)
    preds = [Predicate(tenant=i % 3, min_ts=100) for i in range(G)]
    kw = dict(mode="wsum", w_dense=W_DENSE, w_lex=W_LEX, rrf_c=60.0)

    st_res, st_pg = ExecStats(), ExecStats()
    hot_r = _launch_hybrid(dict(store), lex, q, gids, preds, qterms, k,
                           stats=st_res, shapes=CompiledShapes(), **kw)
    hot_p = _launch_hybrid(dict(store), lex, q, gids, preds, qterms, k,
                           stats=st_pg, shapes=CompiledShapes(),
                           page_rows=256, **kw)
    s_r, i_r = _finish_hot(hot_r)
    s_p, i_p = _finish_hot(hot_p)
    assert (s_r == s_p).all() and (i_r == i_p).all()
    assert st_res.terms_scanned == N * T_LANES
    assert st_pg.terms_scanned == N * T_LANES


# ---------------------------------------------------------------------------
# plan conformance: the planner's paged regime end to end
# ---------------------------------------------------------------------------

def test_paged_plan_execution_bit_identical(rng):
    """compile_plan stamps page_rows past the threshold; execute_plans then
    returns the same bits as the resident plans, counts the paged launches,
    and the EXPLAIN output names the regime."""
    N, D, K = 3000, 16, 8
    store = _arena(rng, N, D)
    q = rng.standard_normal((6, D)).astype(np.float32)
    lps = [LogicalPlan(tenant=t % 3, k=K, q=q[2 * t:2 * t + 2])
           for t in range(3)]
    cfg_res = PlannerConfig()
    cfg_pg = PlannerConfig(paged_min_rows=1, page_rows=512)

    def compiled(cfg):
        return [compile_plan(lp, n_rows=N, hot_window_s=100, now_ts=1000,
                             warm_rows=0, cfg=cfg) for lp in lps]

    plans_res, plans_pg = compiled(cfg_res), compiled(cfg_pg)
    assert plans_res[0].page_rows is None
    assert plans_pg[0].page_rows == 512
    assert "paged arena scan" in plans_pg[0].explain()
    assert "paged regime" in plans_pg[0].engine_reason
    assert plans_res[0].group_key != plans_pg[0].group_key
    assert plans_res[0].fuse_key != plans_pg[0].fuse_key

    st_res, st_pg = ExecStats(), ExecStats()
    s_r, i_r, _ = executor_mod.execute_plans(dict(store), None, plans_res,
                                             stats=st_res)
    s_p, i_p, _ = executor_mod.execute_plans(dict(store), None, plans_pg,
                                             stats=st_pg, planner_cfg=cfg_pg)
    assert (np.asarray(s_r) == np.asarray(s_p)).all()
    assert (np.asarray(i_r) == np.asarray(i_p)).all()
    assert st_res.paged_scans == 0
    assert st_pg.paged_scans >= 1

    # below the threshold the knob stays cold: identical plans, no stamping
    cfg_cold = dataclasses.replace(cfg_pg, paged_min_rows=N + 1)
    assert compiled(cfg_cold)[0].page_rows is None
