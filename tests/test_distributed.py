"""Distribution-layer tests. Multi-device cases run in SUBPROCESSES with
--xla_force_host_platform_device_count (the main test process must keep the
single real device; see conftest)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

pytestmark = [pytest.mark.distributed, pytest.mark.slow]

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_fit_spec_divisibility():
    from repro.distributed.sharding import fit_spec
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    # non-dividing dims fall back to None / a dividing subgroup
    assert fit_spec(mesh, P("data"), (13,)) == P("data")  # 13 % 1 == 0 here
    mesh2 = jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1,), ("x",))
    assert fit_spec(mesh2, P("x", None), (7, 3)) == P("x", None)


def test_fit_spec_logic_pure():
    """Pure spec-fitting logic with a fake mesh shape."""
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
        axis_names = ("pod", "data", "model")
    from repro.distributed.sharding import fit_spec
    m = FakeMesh()
    assert fit_spec(m, P(("pod", "data"), "model"), (64, 64)) == P(("pod", "data"), "model")
    # 49155 divides by nothing here -> None; 1024 / fsdp(32) ok
    got = fit_spec(m, P("model", ("pod", "data")), (49155, 1024))
    assert got == P(None, ("pod", "data"))
    # 1e6 % 256 != 0 but % 16 == 0 -> shrinks to a dividing subgroup
    got = fit_spec(m, P(("data", "model"),), (1_000_000,))
    assert got in (P("data"), P(("data",),))


def test_sharded_kernels_and_vp_loss_subprocess():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels.filtered_topk.ops import filtered_topk_sharded
        from repro.kernels.filtered_topk.ref import filtered_topk_ref
        from repro.kernels.decode_attention.ops import decode_attention_sharded
        from repro.kernels.decode_attention.ref import decode_attention_ref
        from repro.launch.mesh import make_mesh
        from repro.models.transformer import TransformerConfig, init, loss_fn, make_vp_loss_fn

        rng = np.random.default_rng(0)
        mesh = make_mesh((2, 2), ("data", "model"))

        # sharded filtered_topk == global oracle
        N, D, kk = 2048, 64, 7
        q = jnp.asarray(rng.standard_normal((3, D), dtype=np.float32))
        emb = jnp.asarray(rng.standard_normal((N, D), dtype=np.float32))
        meta = jnp.stack([jnp.asarray(rng.integers(-1, 5, N, dtype=np.int32)),
                          jnp.asarray(rng.integers(0, 99, N, dtype=np.int32)),
                          jnp.asarray(rng.integers(0, 4, N, dtype=np.int32)),
                          jnp.asarray(rng.integers(1, 8, N, dtype=np.int32))], 1)
        pred = jnp.array([1, 20, 0b1010, 0b11], jnp.int32)
        s1, i1 = filtered_topk_sharded(mesh, ("data", "model"), q, emb, meta, pred, kk)
        s2, i2 = filtered_topk_ref(q, emb, meta, pred, kk)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)

        # sharded flash-decode == oracle across shard-crossing lengths
        B, S, KV, G, hd = 2, 1024, 2, 4, 64
        qd = jnp.asarray(rng.standard_normal((B, KV*G, hd), dtype=np.float32))
        kc = jnp.asarray(rng.standard_normal((B, S, KV, hd), dtype=np.float32))
        vc = jnp.asarray(rng.standard_normal((B, S, KV, hd), dtype=np.float32))
        lengths = jnp.asarray([300, 900], jnp.int32)
        outd = decode_attention_sharded(mesh, "model", qd, kc, vc, lengths,
                                        n_kv=KV, blk_s=128)
        refd = decode_attention_ref(qd.reshape(B, KV, G, hd), kc, vc,
                                    lengths).reshape(B, KV*G, hd)
        np.testing.assert_allclose(np.asarray(outd), np.asarray(refd),
                                   rtol=2e-5, atol=2e-5)

        # vocab-parallel CE == plain loss (values + grads)
        cfg = TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                                n_kv_heads=2, d_ff=64, vocab_size=128,
                                dtype="float32")
        params = init(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(rng.integers(0, 128, (4, 16), dtype=np.int32))
        batch = {"tokens": toks, "labels": toks}
        vp = make_vp_loss_fn(cfg, mesh)
        np.testing.assert_allclose(float(loss_fn(params, cfg, batch)),
                                   float(vp(params, batch)), rtol=1e-5)
        g1 = jax.grad(loss_fn)(params, cfg, batch)
        g2 = jax.grad(vp)(params, batch)
        for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
        print("SUBPROCESS_OK")
    """)
    assert "SUBPROCESS_OK" in out


def test_mini_dryrun_subprocess():
    """build_cell machinery on a small mesh: one cheap cell per family."""
    out = run_sub("""
        import jax
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_cell
        mesh = make_mesh((2, 2), ("data", "model"))
        for arch, shape in [("qwen1.5-0.5b", "decode_32k"), ("fm", "serve_p99"),
                            ("gcn-cora", "molecule"), ("rag-unified", "ingest")]:
            cell = build_cell(arch, shape, mesh)
            c = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                        out_shardings=cell.out_shardings).lower(*cell.args).compile()
            assert c.memory_analysis() is not None
            print("CELL_OK", arch, shape)
    """, devices=4)
    assert out.count("CELL_OK") == 4


def test_compression_psum_subprocess():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import psum_bf16, psum_int8
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("d",))
        x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 256), np.float32))
        want = np.asarray(x).sum(0)
        for fn, tol in [(psum_bf16, 2e-2), (psum_int8, 4e-2)]:
            f = shard_map(lambda v: fn(v, "d"), mesh=mesh, in_specs=P("d"),
                          out_specs=P("d"), check_rep=False)
            got = np.asarray(f(x))[0]
            rel = np.abs(got - want).max() / np.abs(want).max()
            assert rel < tol, (fn.__name__, rel)
        print("PSUM_OK")
    """, devices=4)
    assert "PSUM_OK" in out
