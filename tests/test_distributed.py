"""Distribution-layer tests. Multi-device cases run in SUBPROCESSES with
--xla_force_host_platform_device_count (the main test process must keep the
single real device; see conftest)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

pytestmark = [pytest.mark.distributed, pytest.mark.slow]

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
TESTS = os.path.dirname(__file__)


def run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join([SRC, TESTS])
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_fit_spec_divisibility():
    from repro.distributed.sharding import fit_spec
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    # non-dividing dims fall back to None / a dividing subgroup
    assert fit_spec(mesh, P("data"), (13,)) == P("data")  # 13 % 1 == 0 here
    mesh2 = jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1,), ("x",))
    assert fit_spec(mesh2, P("x", None), (7, 3)) == P("x", None)


def test_fit_spec_logic_pure():
    """Pure spec-fitting logic with a fake mesh shape."""
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
        axis_names = ("pod", "data", "model")
    from repro.distributed.sharding import fit_spec
    m = FakeMesh()
    assert fit_spec(m, P(("pod", "data"), "model"), (64, 64)) == P(("pod", "data"), "model")
    # 49155 divides by nothing here -> None; 1024 / fsdp(32) ok
    got = fit_spec(m, P("model", ("pod", "data")), (49155, 1024))
    assert got == P(None, ("pod", "data"))
    # 1e6 % 256 != 0 but % 16 == 0 -> shrinks to a dividing subgroup
    got = fit_spec(m, P(("data", "model"),), (1_000_000,))
    assert got in (P("data"), P(("data",),))


def test_sharded_kernels_and_vp_loss_subprocess():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels.filtered_topk.ops import filtered_topk_sharded
        from repro.kernels.filtered_topk.ref import filtered_topk_ref
        from repro.kernels.decode_attention.ops import decode_attention_sharded
        from repro.kernels.decode_attention.ref import decode_attention_ref
        from repro.launch.mesh import make_mesh
        from repro.models.transformer import TransformerConfig, init, loss_fn, make_vp_loss_fn

        rng = np.random.default_rng(0)
        mesh = make_mesh((2, 2), ("data", "model"))

        # sharded filtered_topk == global oracle
        N, D, kk = 2048, 64, 7
        q = jnp.asarray(rng.standard_normal((3, D), dtype=np.float32))
        emb = jnp.asarray(rng.standard_normal((N, D), dtype=np.float32))
        meta = jnp.stack([jnp.asarray(rng.integers(-1, 5, N, dtype=np.int32)),
                          jnp.asarray(rng.integers(0, 99, N, dtype=np.int32)),
                          jnp.asarray(rng.integers(0, 4, N, dtype=np.int32)),
                          jnp.asarray(rng.integers(1, 8, N, dtype=np.int32))], 1)
        pred = jnp.array([1, 20, 0b1010, 0b11], jnp.int32)
        s1, i1 = filtered_topk_sharded(mesh, ("data", "model"), q, emb, meta, pred, kk)
        s2, i2 = filtered_topk_ref(q, emb, meta, pred, kk)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)

        # sharded flash-decode == oracle across shard-crossing lengths
        B, S, KV, G, hd = 2, 1024, 2, 4, 64
        qd = jnp.asarray(rng.standard_normal((B, KV*G, hd), dtype=np.float32))
        kc = jnp.asarray(rng.standard_normal((B, S, KV, hd), dtype=np.float32))
        vc = jnp.asarray(rng.standard_normal((B, S, KV, hd), dtype=np.float32))
        lengths = jnp.asarray([300, 900], jnp.int32)
        outd = decode_attention_sharded(mesh, "model", qd, kc, vc, lengths,
                                        n_kv=KV, blk_s=128)
        refd = decode_attention_ref(qd.reshape(B, KV, G, hd), kc, vc,
                                    lengths).reshape(B, KV*G, hd)
        np.testing.assert_allclose(np.asarray(outd), np.asarray(refd),
                                   rtol=2e-5, atol=2e-5)

        # vocab-parallel CE == plain loss (values + grads)
        cfg = TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                                n_kv_heads=2, d_ff=64, vocab_size=128,
                                dtype="float32")
        params = init(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(rng.integers(0, 128, (4, 16), dtype=np.int32))
        batch = {"tokens": toks, "labels": toks}
        vp = make_vp_loss_fn(cfg, mesh)
        np.testing.assert_allclose(float(loss_fn(params, cfg, batch)),
                                   float(vp(params, batch)), rtol=1e-5)
        g1 = jax.grad(loss_fn)(params, cfg, batch)
        g2 = jax.grad(vp)(params, batch)
        for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
        print("SUBPROCESS_OK")
    """)
    assert "SUBPROCESS_OK" in out


def test_sharded_arena_scan_subprocess():
    """The sharded engine's device-level contracts on an 8-way CPU mesh:
    bit-identity with the dense oracle, the O(S*B*k) collective-payload
    bound asserted from compiled HLO, the per-shard rows audit, and
    placement INVARIANCE under constructed score ties (shuffling which
    shard holds which rows cannot change the returned (score, doc_id)
    lists bit-wise)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.query import unified_query_ref
        from repro.kernels.arena_scan.sharded import (
            make_sharded_arena_scan, sharded_collective_bytes)
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(0)
        N, D, k, S = 4096, 32, 10, 8
        mesh = make_mesh((S,), ("data",))

        def store_of(emb, tenant, cat, ts, doc_id):
            n = emb.shape[0]
            return {"emb": jnp.asarray(emb), "tenant": jnp.asarray(tenant),
                    "category": jnp.asarray(cat, jnp.int32),
                    "updated_at": jnp.asarray(ts, jnp.int32),
                    "acl": jnp.asarray(np.full(n, 3), jnp.uint32),
                    "doc_id": jnp.asarray(doc_id, jnp.int32),
                    "version": jnp.zeros(n, jnp.int32),
                    "commit_ts": jnp.int32(1), "n_live": jnp.int32(n)}

        emb = rng.standard_normal((N, D), dtype=np.float32)
        tenant = rng.integers(0, 16, N).astype(np.int32)
        cat = rng.integers(0, 4, N).astype(np.int32)
        ts = rng.integers(1, 99, N).astype(np.int32)
        store = store_of(emb, tenant, cat, ts, np.arange(N))
        q = rng.standard_normal((3, D), dtype=np.float32)
        pred = jnp.array([-2, 10, -1, -1], jnp.int32)

        fn = make_sharded_arena_scan(mesh, ("data",), N, k)
        s, sl, rows = fn(store, jnp.asarray(q), pred)
        s0, i0 = unified_query_ref(store, jnp.asarray(q), pred, k)
        assert np.array_equal(np.asarray(s), np.asarray(s0))
        assert np.array_equal(np.asarray(sl), np.asarray(i0))
        assert np.asarray(rows).tolist() == [N // S] * S
        print("ORACLE_OK")

        # collective payload: 3 gathered (B_pad, k) lists per shard -> the
        # issue's O(S*B*k) bound, and a vanishing fraction of arena bytes
        cbytes = sharded_collective_bytes(fn, store, jnp.asarray(q), pred)
        B_pad = 8                         # query block lane-padded to 8
        assert 0 < cbytes <= 2 * S * B_pad * k * 8, cbytes
        # (the <0.1%-of-arena-bytes fraction is asserted at bench scale,
        # N=1M, by tools/check_bench_regression.py --sharded-only)
        print("PAYLOAD_OK", cbytes)

        # placement invariance under constructed ties: 64 rows share ONE
        # embedding (exact f32 score ties); shuffle which shard holds which
        # rows and the merged (score, doc_id) lists must not move
        emb_t = emb.copy(); emb_t[:64] = emb_t[0]
        perm = rng.permutation(N)
        docs = np.arange(N)
        fn2 = make_sharded_arena_scan(mesh, ("data",), N, k)
        outs = []
        for order in (docs, perm):
            st2 = store_of(emb_t[order], tenant[order], cat[order],
                           ts[order], docs[order])
            s2, sl2, _ = fn2(st2, jnp.asarray(q), pred)
            sl2 = np.asarray(sl2)
            ids = np.where(sl2 >= 0, docs[order][sl2], -1)
            outs.append((np.asarray(s2), ids))
        assert np.array_equal(outs[0][0], outs[1][0])
        assert np.array_equal(outs[0][1], outs[1][1])
        print("PLACEMENT_INVARIANT_OK")
    """, devices=8)
    assert "ORACLE_OK" in out and "PAYLOAD_OK" in out
    assert "PLACEMENT_INVARIANT_OK" in out


def test_sharded_ragdb_affine_subprocess():
    """End-to-end mesh-built RagDB at S=8 with tenant-affine placement: the
    property-test sweep from test_property_isolation runs here with REAL
    multi-shard structural skips (owning shard only, poisoned foreign shard
    never surfaces, bits match the oracle)."""
    out = run_sub("""
        from test_property_isolation import (_args_from_seed,
                                             _check_sharded_affine_isolation)
        for seed in range(4):
            _check_sharded_affine_isolation(_args_from_seed(seed))
        print("AFFINE_PROPERTY_OK")
    """, devices=8)
    assert "AFFINE_PROPERTY_OK" in out


def test_mini_dryrun_subprocess():
    """build_cell machinery on a small mesh: one cheap cell per family."""
    out = run_sub("""
        import jax
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_cell
        mesh = make_mesh((2, 2), ("data", "model"))
        for arch, shape in [("qwen1.5-0.5b", "decode_32k"), ("fm", "serve_p99"),
                            ("gcn-cora", "molecule"), ("rag-unified", "ingest")]:
            cell = build_cell(arch, shape, mesh)
            c = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                        out_shardings=cell.out_shardings).lower(*cell.args).compile()
            assert c.memory_analysis() is not None
            print("CELL_OK", arch, shape)
    """, devices=4)
    assert out.count("CELL_OK") == 4


def test_compression_psum_subprocess():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import psum_bf16, psum_int8
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("d",))
        x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 256), np.float32))
        want = np.asarray(x).sum(0)
        for fn, tol in [(psum_bf16, 2e-2), (psum_int8, 4e-2)]:
            f = shard_map(lambda v: fn(v, "d"), mesh=mesh, in_specs=P("d"),
                          out_specs=P("d"), check_rep=False)
            got = np.asarray(f(x))[0]
            rel = np.abs(got - want).max() / np.abs(want).max()
            assert rel < tol, (fn.__name__, rel)
        print("PSUM_OK")
    """, devices=4)
    assert "PSUM_OK" in out
