"""Deterministic (fake-clock) tests for the admission-controlled scheduler.

Four contracts from the serving design:

1. ADMISSION BOUNDS THE QUEUE — offered load past `max_queue` is shed at
   admission (cheap refusal), never enqueued; the baseline (admission=False)
   is the unbounded FIFO whose queue grows without limit.
2. DEGRADATION IS BIT-IDENTICAL — every response the scheduler serves
   degraded equals, bit for bit, running that same degraded plan directly
   through `RagDB.execute`. The rung changes WHICH plan runs, never how.
3. DEGRADATION IS AUDITED — applied rungs land in the plan's `explain()`,
   in `ExecStats.degraded_plans`, and in the scheduler's metrics counters:
   no silent quality loss.
4. STALE SERVES RESPECT THE BOUND — past `stale_pressure`, a cached result
   from an older snapshot may be served, but only within the caller's
   declared `stale_within_s`; beyond it the scheduler computes fresh.

All tests drive an injected fake clock: no sleeps, no wall-clock flake.
"""
import numpy as np
import pytest

from repro.api import RagDB
from repro.core import Principal, StoreConfig
from repro.core.store import DocBatch
from repro.serving.metrics import MetricsRegistry
from repro.serving.scheduler import (Scheduler, SchedulerConfig,
                                     ServeRequest)

ALL_BITS = 0xFFFFFFFF
N_DOCS, DIM, N_TENANTS = 512, 16, 4


class FakeClock:
    """Injectable monotonic clock; tests advance it explicitly."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _db(with_index: bool = True) -> tuple[RagDB, np.ndarray]:
    rng = np.random.default_rng(0)
    emb = rng.standard_normal((N_DOCS, DIM), dtype=np.float32)
    db = RagDB(StoreConfig(capacity=N_DOCS, dim=DIM, metric="dot"))
    db.ingest(DocBatch(
        emb=emb,
        tenant=rng.integers(0, N_TENANTS, N_DOCS).astype(np.int32),
        category=rng.integers(0, 8, N_DOCS).astype(np.int32),
        updated_at=np.zeros(N_DOCS, np.int32),
        acl=np.full(N_DOCS, ALL_BITS, np.uint32),
        doc_id=np.arange(N_DOCS, dtype=np.int32)))
    if with_index:
        db.build_index()
    return db, emb


def _plan(db: RagDB, tenant: int, q: np.ndarray, k: int = 4,
          engine: str | None = "ivf"):
    s = db.session(Principal(tenant_id=tenant, group_bits=ALL_BITS))
    b = s.search(q, normalize=False).limit(k)
    if engine is not None:
        b = b.using(engine)
    return b.plan()


def _requests(db, clock, n, *, k=4, engine="ivf", seed=1):
    rng = np.random.default_rng(seed)
    return [ServeRequest(plan=_plan(db, i % N_TENANTS,
                                    rng.standard_normal(DIM,).astype(
                                        np.float32), k=k, engine=engine),
                         arrival_t=clock(), req_id=i, tenant=i % N_TENANTS)
            for i in range(n)]


# -- 1. admission ----------------------------------------------------------

def test_admission_sheds_before_unbounded_queue_growth():
    db, _ = _db()
    clock = FakeClock()
    cfg = SchedulerConfig(max_queue=8, max_batch=4)
    sched = Scheduler(db, cfg, clock=clock)
    admitted = sum(sched.offer(r) for r in _requests(db, clock, 30))
    assert admitted == 8, "admission must stop exactly at max_queue"
    assert len(sched.queue) == 8
    assert sched.shed_count == 22
    assert sched.metrics.counter_total("shed") == 22


def test_baseline_fifo_never_sheds():
    db, _ = _db()
    clock = FakeClock()
    sched = Scheduler(db, SchedulerConfig(max_queue=8, admission=False),
                      clock=clock)
    assert all(sched.offer(r) for r in _requests(db, clock, 30))
    assert len(sched.queue) == 30 and sched.shed_count == 0


# -- 2. degraded responses are bit-identical to the degraded plan ----------

def test_each_degradation_rung_bit_identical_to_direct_execution():
    db, _ = _db()
    clock = FakeClock()
    # tiny queue + zero thresholds: every batch is "pressured" and walks
    # rungs; no cache so every response is a real computation
    sched = Scheduler(db, SchedulerConfig(
        slo_ms=50.0, max_queue=4, max_batch=2, degrade_pressure=0.0,
        use_cache=False), clock=clock)
    reqs = _requests(db, clock, 4)
    for r in reqs:
        sched.offer(r)
    results = sched.run_until_idle()
    assert len(results) == 4
    assert any(res.degraded for res in results), \
        "pressure thresholds at zero must engage the ladder"
    for res in results:
        ran = res.request.plan               # the plan that actually ran
        assert ran.degraded == res.degraded
        s, sl, _ = db.execute([ran], use_cache=False)
        np.testing.assert_array_equal(res.slots, sl)
        np.testing.assert_array_equal(res.scores, s)


def test_every_ladder_rung_bit_identical_standalone():
    """Walk the full ladder by hand: each rung, served through the
    scheduler as the ONLY admitted plan, equals direct execution."""
    db, _ = _db()
    clock = FakeClock()
    rng = np.random.default_rng(3)
    q = rng.standard_normal(DIM).astype(np.float32)
    plan = _plan(db, tenant=1, q=q)
    rungs = [plan]
    while (nxt := db.degrade(rungs[-1])) is not None:
        rungs.append(nxt)
    assert len(rungs) >= 2, "ivf plan must expose at least one rung"
    for rung in rungs:
        sched = Scheduler(db, SchedulerConfig(use_cache=False), clock=clock)
        sched.offer(ServeRequest(plan=rung, arrival_t=clock()))
        (res,) = sched.run_until_idle()
        s, sl, _ = db.execute([rung], use_cache=False)
        np.testing.assert_array_equal(res.slots, sl)
        np.testing.assert_array_equal(res.scores, s)
        assert res.degraded == rung.degraded


# -- 3. degradations are audited -------------------------------------------

def test_degradations_surface_in_explain_stats_and_metrics():
    db, _ = _db()
    clock = FakeClock()
    metrics = MetricsRegistry()
    before = db.stats.degraded_plans
    sched = Scheduler(db, SchedulerConfig(
        max_queue=4, max_batch=2, degrade_pressure=0.0, use_cache=False),
        clock=clock, metrics=metrics)
    for r in _requests(db, clock, 4):
        sched.offer(r)
    results = sched.run_until_idle()
    degraded = [r for r in results if r.degraded]
    assert degraded, "zero thresholds must degrade"
    for res in degraded:
        text = res.request.plan.explain()
        assert "degraded:" in text
        for rung in res.degraded:
            assert rung in text, f"rung {rung!r} missing from explain()"
    assert db.stats.degraded_plans - before == len(degraded)
    assert metrics.counter_total("degradations") >= len(degraded)
    assert "degraded plans" in db.explain()


# -- 4. staleness-bounded cache serves --------------------------------------

def _one_round(sched, db, clock, q, *, tenant=0):
    sched.offer(ServeRequest(plan=_plan(db, tenant, q), arrival_t=clock()))
    (res,) = sched.run_until_idle()
    return res


def test_stale_serve_within_bound_and_fresh_beyond_it():
    db, emb = _db()
    clock = FakeClock()
    rng = np.random.default_rng(5)
    q = rng.standard_normal(DIM).astype(np.float32)
    bound = 10.0
    # stale_pressure=0 -> stale serves allowed whenever the queue is
    # non-empty; large slo so nothing sheds on deadline
    cfg = SchedulerConfig(slo_ms=1e6, max_queue=4, degrade_pressure=0.0,
                          stale_pressure=0.0, stale_within_s=bound)
    sched = Scheduler(db, cfg, clock=clock)

    first = _one_round(sched, db, clock, q)
    assert first.served == "fresh"

    # a write invalidates the exact cache key (commit count moved) ...
    ids = np.arange(8, dtype=np.int64)
    db.update(ids, rng.standard_normal((8, DIM), dtype=np.float32),
              np.full(8, 1, np.int64))
    clock.advance(bound / 2)
    # ... but within the bound the old snapshot may be served
    second = _one_round(sched, db, clock, q)
    assert second.served == "stale"
    assert second.stale_age_s is not None and second.stale_age_s <= bound
    np.testing.assert_array_equal(second.slots, first.slots)
    assert sched.metrics.counter_total("stale_serves") == 1
    assert db.stats.stale_serves == 1

    # beyond the bound the entry is too old: recompute fresh
    clock.advance(bound)
    third = _one_round(sched, db, clock, q)
    assert third.served == "fresh"


def test_no_stale_serve_when_bound_not_declared():
    db, _ = _db()
    clock = FakeClock()
    rng = np.random.default_rng(6)
    q = rng.standard_normal(DIM).astype(np.float32)
    cfg = SchedulerConfig(slo_ms=1e6, max_queue=4, degrade_pressure=0.0,
                          stale_pressure=0.0, stale_within_s=None)
    sched = Scheduler(db, cfg, clock=clock)
    assert _one_round(sched, db, clock, q).served == "fresh"
    ids = np.arange(8, dtype=np.int64)
    db.update(ids, rng.standard_normal((8, DIM), dtype=np.float32),
              np.full(8, 1, np.int64))
    assert _one_round(sched, db, clock, q).served == "fresh"


# -- pipelining ------------------------------------------------------------

def test_step_pipelines_one_batch_deep():
    """step() launches batch N+1 before finishing batch N: the first step
    returns nothing (its batch is in flight), the second returns the
    first's results."""
    db, _ = _db()
    clock = FakeClock()
    sched = Scheduler(db, SchedulerConfig(max_batch=2, use_cache=False),
                      clock=clock)
    for r in _requests(db, clock, 4):
        sched.offer(r)
    first = sched.step()
    assert first == [] and len(sched._pending) == 1
    second = sched.step()
    assert len(second) == 2 and len(sched._pending) == 1
    assert len(sched.flush()) == 2
    assert not sched.busy


# -- watchdog: wedged batches are refused, requeued, and re-served ---------

def _watchdog_sched(db, clock, **over):
    from repro.serving.faults import FaultPlan, FaultRule  # noqa: F401
    base = dict(slo_ms=1e9, max_queue=16, max_batch=4, degrade_pressure=2.0,
                stale_pressure=2.0, use_cache=False, watchdog_ms=100.0,
                requeue_limit=1)
    base.update(over)
    return Scheduler(db, SchedulerConfig(**base), clock=clock,
                     metrics=MetricsRegistry(), sleep=clock.advance)


def test_watchdog_refuses_wedged_batch_and_requeues_to_clean_result():
    """A batch that stalls 10s past a 100ms watchdog is refused; its
    requests requeue and the retry (fault exhausted) serves clean."""
    from repro.serving.faults import FaultPlan, FaultRule
    db, _ = _db()
    clock = FakeClock()
    db.attach_faults(FaultPlan(
        0, {"hot.wedge": FaultRule(at=(0,), stall_s=10.0)},
        sleep=clock.advance))
    sched = _watchdog_sched(db, clock)
    reqs = _requests(db, clock, 4)
    for r in reqs:
        assert sched.offer(r)
    results = sched.run_until_idle()
    assert len(results) == 4, "refused batch must still resolve every request"
    assert sched.metrics.counter_total("watchdog_fired") == 1
    assert sched.metrics.counter_total("requeued") == 4
    assert all(r.served != "failed" for r in results)
    # the re-served answers equal direct execution of the same plans
    db.attach_faults(None)
    for res in results:
        s, sl, tr = db.execute([res.request.plan], use_cache=False)
        np.testing.assert_array_equal(res.slots, sl)
        np.testing.assert_array_equal(res.scores, s)


def test_finish_fault_is_requeued_then_served():
    from repro.serving.faults import FaultPlan, FaultRule
    db, _ = _db()
    clock = FakeClock()
    db.attach_faults(FaultPlan(
        0, {"hot.finish_error": FaultRule(at=(0,))}, sleep=clock.advance))
    sched = _watchdog_sched(db, clock)
    for r in _requests(db, clock, 2, seed=2):
        assert sched.offer(r)
    results = sched.run_until_idle()
    assert len(results) == 2
    assert sched.metrics.counter_total("finish_faults") == 1
    assert all(r.served != "failed" for r in results)
    db.attach_faults(None)


def test_watchdog_exhaustion_fails_explicitly():
    """A batch that wedges on EVERY attempt exhausts requeue_limit and is
    failed with sentinel results — never silently wrong, never stuck."""
    from repro.serving.faults import FaultPlan, FaultRule
    db, _ = _db()
    clock = FakeClock()
    db.attach_faults(FaultPlan(
        0, {"hot.wedge": FaultRule(rate=1.0, stall_s=10.0)},
        sleep=clock.advance))
    sched = _watchdog_sched(db, clock)
    for r in _requests(db, clock, 2, seed=3):
        assert sched.offer(r)
    results = sched.run_until_idle()
    assert len(results) == 2
    assert all(r.served == "failed" for r in results)
    assert all((r.slots == -1).all() for r in results)
    assert not any(r.deadline_met for r in results)
    assert sched.metrics.counter_total("watchdog_fired") == 2
    assert sched.metrics.counter_total("failed") == 2
    db.attach_faults(None)
