"""Doctest smoke for the front-door API and serving engine docstrings.

Every ``>>>`` example in these modules is executed here, so the runnable
examples referenced from docs/api.md cannot rot. (Equivalent to
``pytest --doctest-modules src/repro/api`` but explicit about the module
list, so adding a slow-to-import module elsewhere can't bloat tier-1.)
"""
import doctest

import pytest

import repro.api.executor
import repro.api.plan
import repro.api.planner
import repro.api.ragdb
import repro.index.lexical.arena
import repro.obs.calibration
import repro.obs.recorder
import repro.obs.tracer
import repro.serving.engine
import repro.serving.metrics

MODULES = [
    repro.api.plan,
    repro.api.planner,
    repro.api.executor,
    repro.api.ragdb,
    repro.index.lexical.arena,
    repro.serving.engine,
    repro.serving.metrics,
    repro.obs.tracer,
    repro.obs.recorder,
    repro.obs.calibration,
]


@pytest.mark.parametrize("mod", MODULES, ids=lambda m: m.__name__)
def test_module_doctests(mod):
    result = doctest.testmod(mod, verbose=False)
    assert result.attempted > 0 or mod is repro.serving.engine, \
        f"{mod.__name__} lost its doctest examples"
    assert result.failed == 0, f"{result.failed} doctest failure(s) in {mod.__name__}"
