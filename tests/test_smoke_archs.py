"""Per-architecture smoke tests: REDUCED config of the same family, one real
forward/train step on CPU, asserting output shapes and finiteness. The FULL
configs are exercised only by the multi-pod dry-run (ShapeDtypeStructs)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import gnn as gnn_mod
from repro.models import recsys as rec
from repro.models import transformer as tfm
from repro.training.optimizer import adamw
from repro.training.train_loop import init_state, make_train_step

pytestmark = [pytest.mark.slow]

LM_ARCHS = [a for a, v in ARCHS.items() if v.family == "lm"]
RECSYS_ARCHS = [a for a, v in ARCHS.items() if v.family == "recsys"]


def _one_train_step(loss_fn, params, batch):
    opt = adamw(1e-3, weight_decay=0.0)
    step = make_train_step(loss_fn, opt, donate=False)
    state, metrics = step(init_state(params, opt), batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), "loss not finite"
    for leaf in jax.tree_util.tree_leaves(state["params"]):
        assert np.isfinite(np.asarray(leaf)).all(), "params went non-finite"
    return loss


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke(arch_id, rng):
    cfg: tfm.TransformerConfig = ARCHS[arch_id].reduced
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32))
    logits, aux = tfm.forward(params, cfg, toks)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = _one_train_step(lambda p, b: tfm.loss_fn(p, cfg, b), params,
                           {"tokens": toks, "labels": toks})
    # untrained loss should be near ln(V)
    assert abs(loss - np.log(cfg.vocab_size)) < 2.0
    # serve path: prefill + one decode step
    lg, cache = tfm.prefill(params, cfg, toks, cache_len=S + 4)
    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
    lg2, _ = tfm.decode_step(params, cfg, nxt, cache, jnp.int32(S))
    assert lg2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()


def _recsys_smoke_batch(arch_id, cfg, rng, B=16):
    if arch_id == "dlrm-rm2":
        return {"dense": jnp.asarray(rng.standard_normal((B, cfg.n_dense), dtype=np.float32)),
                "sparse_ids": jnp.asarray(rng.integers(0, cfg.vocab, (B, cfg.n_sparse, cfg.multi_hot), dtype=np.int32)),
                "label": jnp.asarray(rng.integers(0, 2, B, dtype=np.int32))}
    if arch_id == "fm":
        return {"sparse_ids": jnp.asarray(rng.integers(0, cfg.vocab, (B, cfg.n_sparse), dtype=np.int32)),
                "label": jnp.asarray(rng.integers(0, 2, B, dtype=np.int32))}
    if arch_id == "mind":
        return {"hist_ids": jnp.asarray(rng.integers(0, cfg.vocab, (B, cfg.hist_len), dtype=np.int32)),
                "hist_mask": jnp.ones((B, cfg.hist_len), bool),
                "label_id": jnp.asarray(rng.integers(0, cfg.vocab, B, dtype=np.int32))}
    if arch_id == "bert4rec":
        S, M = cfg.seq_len, 3
        ids = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
        pos = rng.integers(0, S, (B, M)).astype(np.int32)
        tgt = np.take_along_axis(ids, pos, 1)
        np.put_along_axis(ids, pos, cfg.mask_id, 1)
        return {"ids": jnp.asarray(ids), "pad_mask": jnp.ones((B, S), bool),
                "mask_positions": jnp.asarray(pos), "mask_targets": jnp.asarray(tgt)}
    raise KeyError(arch_id)


RECSYS_FNS = {
    "dlrm-rm2": (rec.dlrm_init, rec.dlrm_loss),
    "fm": (rec.fm_init, rec.fm_loss),
    "mind": (rec.mind_init, rec.mind_loss),
    "bert4rec": (rec.bert4rec_init, rec.bert4rec_loss),
}


@pytest.mark.parametrize("arch_id", RECSYS_ARCHS)
def test_recsys_smoke(arch_id, rng):
    cfg = ARCHS[arch_id].reduced
    init_fn, loss_fn = RECSYS_FNS[arch_id]
    params = init_fn(jax.random.PRNGKey(0), cfg)
    batch = _recsys_smoke_batch(arch_id, cfg, rng)
    loss = _one_train_step(lambda p, b: loss_fn(p, cfg, b), params, batch)
    assert loss > 0


@pytest.mark.parametrize("shape_kind", ["full", "sampled", "batched"])
def test_gnn_smoke(shape_kind, rng):
    base = ARCHS["gcn-cora"].reduced
    if shape_kind == "batched":
        cfg = dataclasses.replace(base, d_feat=8, n_classes=2)
        B, Nn, Ne = 4, 10, 24
        params = gnn_mod.gcn_init(jax.random.PRNGKey(0), cfg)
        batch = {"feats": jnp.asarray(rng.standard_normal((B, Nn, 8), dtype=np.float32)),
                 "src": jnp.asarray(rng.integers(0, Nn, (B, Ne), dtype=np.int32)),
                 "dst": jnp.asarray(rng.integers(0, Nn, (B, Ne), dtype=np.int32)),
                 "edge_mask": jnp.ones((B, Ne), bool),
                 "node_mask": jnp.ones((B, Nn), bool),
                 "labels": jnp.asarray(rng.integers(0, 2, B, dtype=np.int32))}
        loss = _one_train_step(lambda p, b: gnn_mod.gcn_loss_batched(p, cfg, b),
                               params, batch)
        assert loss > 0
        return
    cfg = base
    if shape_kind == "sampled":
        # real sampler -> padded fixed-shape subgraph -> jitted step
        N, E = 80, 400
        src = rng.integers(0, N, E).astype(np.int32)
        dst = rng.integers(0, N, E).astype(np.int32)
        samp = gnn_mod.NeighborSampler(N, src, dst, seed=1)
        sub = samp.sample(np.arange(8), (4, 3))
        n_sub = sub["nodes"].shape[0]
        feats = rng.standard_normal((N, cfg.d_feat)).astype(np.float32)
        sub_feats = np.where(sub["nodes"][:, None] >= 0,
                             feats[np.maximum(sub["nodes"], 0)], 0.0)
        labels = rng.integers(0, cfg.n_classes, n_sub).astype(np.int32)
        lmask = np.zeros(n_sub, np.float32)
        lmask[:8] = 1.0                                 # loss on seeds only
        batch = {"feats": jnp.asarray(sub_feats), "src": jnp.asarray(sub["src"]),
                 "dst": jnp.asarray(sub["dst"]),
                 "edge_mask": jnp.asarray(sub["edge_mask"]),
                 "labels": jnp.asarray(labels), "label_mask": jnp.asarray(lmask)}
    else:
        N, E = 50, 200
        batch = {"feats": jnp.asarray(rng.standard_normal((N, cfg.d_feat), dtype=np.float32)),
                 "src": jnp.asarray(rng.integers(0, N, E, dtype=np.int32)),
                 "dst": jnp.asarray(rng.integers(0, N, E, dtype=np.int32)),
                 "labels": jnp.asarray(rng.integers(0, cfg.n_classes, N, dtype=np.int32)),
                 "label_mask": jnp.ones(N, np.float32)}
    params = gnn_mod.gcn_init(jax.random.PRNGKey(0), cfg)
    loss = _one_train_step(lambda p, b: gnn_mod.gcn_loss(p, cfg, b), params, batch)
    assert loss > 0


def test_rag_reduced_smoke(rng):
    """The paper's own arch at reduced scale: ingest -> unified query."""
    from repro.configs.rag_unified import REDUCED, REDUCED_CORPUS
    from repro.core import Predicate, TransactionLog, empty, unified_query
    from repro.data.corpus import make_corpus, make_queries
    log = TransactionLog(REDUCED, empty(REDUCED))
    log.ingest(make_corpus(REDUCED_CORPUS))
    q = make_queries(REDUCED_CORPUS, 1, batch=2)[0]
    s, slots = unified_query(log.snapshot(), q, Predicate(tenant=1), k=4)
    assert s.shape == (2, 4) and np.isfinite(np.asarray(s)).any()


def test_registry_covers_assigned_cells():
    from repro.configs import assigned_cells
    cells = assigned_cells()
    assert len(cells) == 40, f"expected 40 assigned cells, got {len(cells)}"
    assert len({a for a, _ in cells}) == 10
