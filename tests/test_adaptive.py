"""The adaptive serving fast path (PR 2).

Acceptance contracts:
  * bucketed execution is a pure shape transform — results bit-identical to
    exact-shape execution across every bucket boundary;
  * the result cache is snapshot-exact — a hit is only possible against the
    same (predicate group, query, commit counters), and any write bumps a
    counter, so post-write queries recompute and match the uncached ref path
    bit for bit;
  * the planner's cost model picks the measured-cheapest engine and falls
    back to the static thresholds when measurements are missing;
  * `TieredRouter.query` surfaces the planner's engine/route choice in its
    return metadata;
  * explain() output follows the exact line format documented in docs/api.md.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import CompiledShapes, LogicalPlan, RagDB
from repro.api import executor as executor_mod
from repro.api.plan import bucket_rows
from repro.api.planner import CostModel, PlannerConfig, choose_engine
from repro.core import Predicate, Principal, StoreConfig, unified_query_ref
from repro.core.router import TieredResult
from repro.data.corpus import DAY_S, CorpusConfig, make_corpus


@pytest.fixture(scope="module")
def db_stack():
    ccfg = CorpusConfig(n_docs=1500, dim=16, n_tenants=4, n_categories=4)
    db = RagDB(StoreConfig(capacity=2048, dim=16))
    db.ingest(make_corpus(ccfg))
    return db, ccfg


# ---------------------------------------------------------------------------
# bucketed batching
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", list(range(1, 10)) + [16, 17])
def test_bucketed_bit_identical_across_boundaries(db_stack, rng, batch):
    """Padding a group to its pow2 bucket must not perturb a single bit of
    the real rows — checked on both sides of every small bucket boundary."""
    db, ccfg = db_stack
    snap = db.log.snapshot()
    q = rng.standard_normal((batch, ccfg.dim)).astype(np.float32)
    preds = [Predicate(tenant=1)] * batch
    es, ei, _ = executor_mod.run_grouped(snap, q, preds, 5)           # exact
    bs, bi, _ = executor_mod.run_grouped(snap, q, preds, 5,
                                         shapes=CompiledShapes())    # bucketed
    assert (es == bs).all() and (ei == bi).all()


def test_bucketed_session_path_bit_identical(db_stack, rng):
    """The front-door path (db.execute with its shape cache) returns exactly
    what the raw ref call returns, for batch sizes needing padding."""
    db, ccfg = db_stack
    sess = db.session(Principal(tenant_id=2, group_bits=0xFFFFFFFF))
    q = rng.standard_normal((5, ccfg.dim)).astype(np.float32)        # bucket 8
    res = sess.search(q).limit(4).run()
    qn = q / np.maximum(np.linalg.norm(q, axis=1, keepdims=True), 1e-12)
    s, sl = unified_query_ref(db.log.snapshot(), jnp.asarray(qn),
                              res.plan.pred.as_array(), 4)
    assert (np.asarray(sl) == res.slots).all()
    assert (np.asarray(s) == res.scores).all()


def test_shape_cache_buckets_collapse_batch_sizes(db_stack, rng):
    """Every batch size in (2^(b-1), 2^b] maps to one resident shape."""
    db, ccfg = db_stack
    snap = db.log.snapshot()
    shapes = CompiledShapes()
    for b in (5, 6, 7, 8):                     # all land in bucket 8
        q = rng.standard_normal((b, ccfg.dim)).astype(np.float32)
        executor_mod.run_grouped(snap, q, [Predicate()] * b, 3, shapes=shapes)
    assert len(shapes) == 1
    assert (shapes.hits, shapes.misses) == (3, 1)


def test_shape_cache_lru_eviction():
    shapes = CompiledShapes(cap=2)
    assert shapes.touch("ref", 4, 5) is False
    assert shapes.touch("ref", 4, 5) is True
    shapes.touch("ref", 8, 5)
    shapes.touch("ref", 16, 5)                 # evicts bucket 4
    assert shapes.touch("ref", 4, 5) is False  # re-entry counts as recompile
    assert len(shapes) == 2


def test_padded_rows_counted(db_stack, rng):
    db, ccfg = db_stack
    before = db.stats.padded_rows
    sess = db.session(Principal(tenant_id=0, group_bits=0xFFFFFFFF))
    q = rng.standard_normal((3, ccfg.dim)).astype(np.float32)        # bucket 4
    sess.search(q).limit(2).run()
    assert db.stats.padded_rows == before + 1


# ---------------------------------------------------------------------------
# snapshot-exact result cache
# ---------------------------------------------------------------------------

def _mini_db(rng, n=300, dim=8, capacity=512, **kwargs):
    ccfg = CorpusConfig(n_docs=n, dim=dim, n_tenants=3, n_categories=4)
    db = RagDB(StoreConfig(capacity=capacity, dim=dim), **kwargs)
    db.ingest(make_corpus(ccfg))
    return db, ccfg


def test_result_cache_hits_same_snapshot(rng):
    db, ccfg = _mini_db(rng)
    sess = db.session(Principal(tenant_id=1, group_bits=0xFFFFFFFF))
    q = rng.standard_normal(ccfg.dim).astype(np.float32)
    r1 = sess.search(q).limit(4).run()
    calls = db.stats.device_calls
    r2 = sess.search(q).limit(4).run()
    assert not r1.cached and r2.cached
    assert db.stats.device_calls == calls          # hit did no device work
    assert (r1.scores == r2.scores).all() and (r1.slots == r2.slots).all()
    # a different query vector is a different key, never a false hit
    r3 = sess.search(q + 1.0).limit(4).run()
    assert not r3.cached


def test_result_cache_invalidated_by_writes_bit_identical(rng):
    """insert/delete bumps commit_count -> miss -> fresh results identical to
    the uncached ref path (the satellite's acceptance contract)."""
    from tests.test_core_store import make_batch
    db, ccfg = _mini_db(rng)
    sess = db.session(Principal(tenant_id=0, group_bits=0xFFFFFFFF))
    q = rng.standard_normal(ccfg.dim).astype(np.float32)
    run = lambda: sess.search(q).limit(5).run()
    base = run()
    assert run().cached
    # INSERT: a new tenant-0 doc invalidates; the fresh result sees it
    db.ingest(make_batch(rng, 1, ccfg.dim, tenant=0, start_id=10_000))
    after_insert = run()
    assert not after_insert.cached
    # DELETE the current top hit: the cached entry must not resurface it
    top = int(base.slots[0, 0])
    top_doc = int(np.asarray(db.log.snapshot()["doc_id"])[top])
    db.delete([top_doc])
    after_delete = run()
    assert not after_delete.cached
    assert top not in after_delete.slots[0].tolist()
    # bit-identity with the uncached ref path on the new snapshot
    qn = np.atleast_2d(q)
    qn = qn / np.maximum(np.linalg.norm(qn, axis=1, keepdims=True), 1e-12)
    s, sl = unified_query_ref(db.log.snapshot(), jnp.asarray(qn),
                              after_delete.plan.pred.as_array(), 5)
    assert (np.asarray(sl) == after_delete.slots).all()
    assert (np.asarray(s) == after_delete.scores).all()


def test_result_cache_update_invalidates(rng):
    db, ccfg = _mini_db(rng)
    sess = db.session(Principal(tenant_id=1, group_bits=0xFFFFFFFF))
    q = rng.standard_normal(ccfg.dim).astype(np.float32)
    base = sess.search(q).limit(3).run()
    top = int(base.slots[0, 0])
    doc = int(np.asarray(db.log.snapshot()["doc_id"])[top])
    db.update([doc], -q[None, :], [ccfg.now_ts])   # re-embed away from q
    fresh = sess.search(q).limit(3).run()
    assert not fresh.cached
    assert fresh.slots[0, 0] != top


def test_warm_writes_invalidate_only_warm_probing_plans(rng):
    """hot+warm entries key on the warm commit counter; hot-only entries pin
    it to -1 and survive warm-tier writes."""
    ccfg = CorpusConfig(n_docs=400, dim=8, n_tenants=3)
    scfg = StoreConfig(capacity=1024, dim=8)
    db = RagDB(scfg, warm_cfg=scfg, hot_window_s=90 * DAY_S, now_ts=ccfg.now_ts)
    corpus = make_corpus(ccfg)
    db.ingest(corpus)
    rng_q = np.random.default_rng(1)
    q = rng_q.standard_normal(ccfg.dim).astype(np.float32)
    admin = db.admin_session()
    hot_only = lambda: (admin.search(q)
                        .newer_than(ccfg.now_ts - 30 * DAY_S).limit(3).run())
    merged = lambda: admin.search(q).limit(3).run()
    assert hot_only().plan.route == "hot" and merged().plan.route == "hot+warm"
    assert hot_only().cached and merged().cached
    # delete one warm doc: warm commit_count bumps, hot commit_count doesn't
    ts = np.asarray(corpus.updated_at)
    warm_doc = int(np.asarray(corpus.doc_id)[np.argsort(ts)[0]])
    assert db.router.warm.has_doc(warm_doc)
    db.delete([warm_doc])
    assert merged().cached is False       # warm-probing plan recomputes
    assert hot_only().cached is True      # hot-only plan provably unaffected


def test_result_cache_disabled(rng):
    db, ccfg = _mini_db(rng, result_cache_size=0)
    assert db.result_cache is None
    sess = db.session(Principal(tenant_id=0, group_bits=0xFFFFFFFF))
    q = rng.standard_normal(ccfg.dim).astype(np.float32)
    assert not sess.search(q).limit(3).run().cached
    assert not sess.search(q).limit(3).run().cached


def test_cache_isolation_across_principals(rng):
    """Two principals issuing the same vector never share an entry: the
    group key carries the tenant/ACL clauses."""
    db, ccfg = _mini_db(rng)
    q = rng.standard_normal(ccfg.dim).astype(np.float32)
    t0 = db.session(Principal(tenant_id=0, group_bits=0xFFFFFFFF))
    t1 = db.session(Principal(tenant_id=1, group_bits=0xFFFFFFFF))
    r0 = t0.search(q).limit(4).run()
    r1 = t1.search(q).limit(4).run()
    assert not r1.cached                  # different predicate group
    tenant_of = np.asarray(db.log.snapshot()["tenant"])
    assert (tenant_of[r0.slots[r0.slots >= 0]] == 0).all()
    assert (tenant_of[r1.slots[r1.slots >= 0]] == 1).all()


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_cost_model_picks_measured_cheapest():
    cm = CostModel(curves=(("ref", ((1 << 10, 1.0), (1 << 20, 1000.0))),
                           ("sharded", ((1 << 10, 8.0), (1 << 20, 80.0)))))
    cfg = PlannerConfig(cost_model=cm)
    eng, why = choose_engine(LogicalPlan(k=5), n_rows=1 << 20, cfg=cfg,
                             has_mesh=True)
    assert eng == "sharded" and "cost model" in why and "ref ~" in why
    eng, _ = choose_engine(LogicalPlan(k=5), n_rows=1 << 10, cfg=cfg,
                           has_mesh=True)
    assert eng == "ref"


def test_cost_model_falls_back_without_full_coverage():
    """A candidate engine with no curve -> the static thresholds decide
    (partial measurements must not silently bias the choice)."""
    cm = CostModel(curves=(("ref", ((1 << 10, 1.0),)),))
    cfg = PlannerConfig(cost_model=cm, shard_min_rows=1 << 20)
    eng, why = choose_engine(LogicalPlan(k=5), n_rows=1 << 21, cfg=cfg,
                             has_mesh=True)
    assert eng == "sharded" and "cost model" not in why
    eng, _ = choose_engine(LogicalPlan(k=5), n_rows=1 << 12, cfg=cfg,
                           has_mesh=True)
    assert eng == "ref"


def test_cost_model_interpolation_and_single_point():
    cm = CostModel(curves=(("ref", ((1000, 1.0), (4000, 4.0))),))
    assert cm.estimate_ms("ref", 1000) == pytest.approx(1.0)
    assert cm.estimate_ms("ref", 2000) == pytest.approx(2.0)    # log-log interp
    assert cm.estimate_ms("ref", 8000) == pytest.approx(8.0)    # extrapolation
    one = CostModel(curves=(("ref", ((1000, 2.0),)),))
    assert one.estimate_ms("ref", 3000) == pytest.approx(6.0)   # row-linear
    assert cm.estimate_ms("pallas", 1000) is None


def test_cost_model_from_bench_roundtrip(tmp_path):
    import json
    path = tmp_path / "bench_latency.json"
    path.write_text(json.dumps({
        "cost_model": {"engines": {"ref": [[1024, 0.5], [4096, 2.0]]},
                       "warm_probe_ms": 3.5}}))
    cm = CostModel.from_bench(str(path))
    assert cm is not None
    assert cm.estimate_ms("ref", 1024) == pytest.approx(0.5)
    assert cm.warm_probe_ms == pytest.approx(3.5)
    assert CostModel.from_bench(str(tmp_path / "missing.json")) is None
    cfg = PlannerConfig.with_measured_costs(str(path))
    assert cfg.cost_model == cm


def test_cost_estimate_lands_in_plan_and_explain(rng):
    db, ccfg = _mini_db(rng)
    cm = CostModel(curves=(("ref", ((256, 0.5), (4096, 4.0))),),
                   warm_probe_ms=2.0)
    db.planner_cfg = PlannerConfig(cost_model=cm)
    sess = db.session(Principal(tenant_id=0, group_bits=0xFFFFFFFF))
    plan = sess.search(rng.standard_normal(ccfg.dim).astype(np.float32)).plan()
    assert plan.cost_source == "measured" and plan.est_cost_ms is not None
    assert "ms/query est (measured curves)" in plan.explain()


# ---------------------------------------------------------------------------
# explain() formats (mirrors docs/api.md)
# ---------------------------------------------------------------------------

PLAN_EXPLAIN_FIELDS = ["predicate:", "engine:", "route:", "batching:",
                       "fusion:", "bucket:", "cost:"]
DB_EXPLAIN_FIELDS = ["planner:", "shape cache:", "result cache:",
                     "exec stats:", "grouped scan:", "serving:",
                     "ivf index:"]


def test_plan_explain_matches_documented_format(db_stack, rng):
    db, ccfg = db_stack
    sess = db.session(Principal(tenant_id=1, group_bits=0xFFFFFFFF))
    text = sess.search(rng.standard_normal(ccfg.dim).astype(np.float32)) \
               .limit(4).explain()
    lines = text.splitlines()
    assert lines[0].startswith("PhysicalPlan  top-4 over ")
    for line, field in zip(lines[1:], PLAN_EXPLAIN_FIELDS):
        assert line.strip().startswith(field), (line, field)
    assert "pow2 shape reuse" in text


def test_db_explain_matches_documented_format(rng):
    db, ccfg = _mini_db(rng)
    sess = db.session(Principal(tenant_id=0, group_bits=0xFFFFFFFF))
    q = rng.standard_normal(ccfg.dim).astype(np.float32)
    sess.search(q).limit(3).run()
    sess.search(q).limit(3).run()
    text = db.explain()
    lines = text.splitlines()
    assert lines[0].startswith("RagDB  ")
    for line, field in zip(lines[1:], DB_EXPLAIN_FIELDS):
        assert line.strip().startswith(field), (line, field)
    assert "1 hits" in text            # the second run() hit the result cache


# ---------------------------------------------------------------------------
# TieredRouter.query metadata (satellite fix)
# ---------------------------------------------------------------------------

def test_router_query_surfaces_engine_and_route(rng):
    ccfg = CorpusConfig(n_docs=500, dim=8, n_tenants=3)
    scfg = StoreConfig(capacity=1024, dim=8)
    from repro.core.router import TieredRouter
    router = TieredRouter(scfg, scfg, hot_window_s=90 * DAY_S,
                          now_ts=ccfg.now_ts)
    router.ingest(make_corpus(ccfg))
    q = jnp.asarray(rng.standard_normal((2, ccfg.dim)).astype(np.float32))
    res = router.query(q, Predicate(), 4)
    assert isinstance(res, TieredResult)
    assert res.engine == "ref"            # planner's choice on a CPU rig
    assert res.route == "hot+warm"
    scores, slots, tiers = res            # 3-tuple unpacking still works
    assert scores.shape == slots.shape == tiers.shape == (2, 4)
    res2 = router.query(q, Predicate(min_ts=ccfg.now_ts - 10 * DAY_S), 4)
    assert res2.route == "hot"
    forced = router.query(q, Predicate(), 4, engine="ref")
    assert forced.engine == "ref"
