"""The ivf engine end to end (sub-linear retrieval PR).

Acceptance contracts:
  * isolation through the pruned route is STRUCTURAL — the predicate mask
    reads arena metadata, so even an adversarially poisoned member table
    cannot surface a row that fails the predicate;
  * recall@10 >= 0.95 vs the exact ref scan across a seed grid;
  * the Pallas probe kernel (interpret mode) is bit-identical to the jnp
    ref probe;
  * the planner's selectivity guard falls back to an exact engine with an
    auditable reason; `.using("ivf")` overrides it;
  * the result cache stays snapshot-exact across writes that touch the
    index and across index rebuilds (epoch-keyed);
  * build overflow rows are scanned exactly, never dropped from recall;
  * `ExecStats.rows_scanned` audits the pruning: probed scans stay under
    25% of the arena, exact scans count the full arena.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import LogicalPlan, RagDB
from repro.api.planner import choose_engine
from repro.core import Predicate, Principal, StoreConfig
from repro.core.ivf import IVFConfig, build_ivf
from repro.data.corpus import CorpusConfig, make_corpus, make_queries
from repro.kernels.ivf_probe.ops import ivf_probe

pytestmark = [pytest.mark.kernels, pytest.mark.slow]


def _db(n_docs=4000, dim=32, n_tenants=4, seed=0, index_cfg=None, **kwargs):
    ccfg = CorpusConfig(n_docs=n_docs, dim=dim, n_tenants=n_tenants,
                        n_categories=4, seed=seed)
    cap = 1 << int(np.ceil(np.log2(n_docs)) + 1)
    db = RagDB(StoreConfig(capacity=cap, dim=dim), **kwargs)
    db.ingest(make_corpus(ccfg))
    db.build_index(index_cfg)
    return db, ccfg


@pytest.fixture(scope="module")
def db_stack():
    return _db()


# ---------------------------------------------------------------------------
# recall vs the exact scan (seed grid)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_recall_at_10_on_seed_grid(seed):
    db, ccfg = _db(n_docs=3000, dim=32, seed=seed)
    admin = db.admin_session()
    qs = np.asarray(make_queries(ccfg, 16, batch=1, seed=seed + 100))
    hits = total = 0
    for q in qs:
        iv = admin.search(q[0]).limit(10).using("ivf").run()
        ex = admin.search(q[0]).limit(10).using("ref").run()
        hits += len(set(iv.slots[0].tolist()) & set(ex.slots[0].tolist()))
        total += 10
    assert hits / total >= 0.95, f"recall@10 {hits / total:.3f} below bar"


# ---------------------------------------------------------------------------
# kernel vs ref probe: bit identity in interpret mode (tier-1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,dim,k,cap_override,B", [
    (1500, 32, 5, None, 2),
    (1200, 48, 8, 64, 4),     # D not a lane multiple + forced overflow tail
    (900, 64, 10, None, 11),  # B above blk_b -> query-row padding path
])
def test_probe_kernel_bit_identical_to_ref(n, dim, k, cap_override, B, rng):
    ccfg = CorpusConfig(n_docs=n, dim=dim, n_tenants=4, n_categories=4)
    from repro.core import TransactionLog, empty
    scfg = StoreConfig(capacity=1 << int(np.ceil(np.log2(n)) + 1), dim=dim)
    log = TransactionLog(scfg, empty(scfg))
    log.ingest(make_corpus(ccfg))
    snap = log.snapshot()
    index = build_ivf(snap, IVFConfig(n_clusters=16, cluster_cap=cap_override))
    if cap_override is not None:
        assert len(index.overflow) > 0, "this case must exercise the tail"
    q = np.asarray(make_queries(ccfg, 1, batch=B, seed=7))[0]
    clusters, _, _ = index.probe(q, nprobe=6)
    dev = index.device_arrays()
    pred = Predicate(min_ts=3, cat_mask=0b0111).as_array()
    args = (jnp.asarray(q), snap["emb"], snap["tenant"], snap["updated_at"],
            snap["category"], snap["acl"], dev["members"], dev["overflow"],
            clusters, pred, k)
    s_ref, i_ref = ivf_probe(*args, use_kernel=False)
    s_ker, i_ker = ivf_probe(*args, use_kernel=True, interpret=True)
    assert (np.asarray(s_ref) == np.asarray(s_ker)).all()
    assert (np.asarray(i_ref) == np.asarray(i_ker)).all()


# ---------------------------------------------------------------------------
# isolation: a poisoned member table cannot leak
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_poisoned_member_table_cannot_leak(seed):
    """Adversarial index corruption — wrong-cluster slots, duplicate slots,
    tombstoned slots, out-of-range slots — may cost recall, never isolation:
    the mask reads ARENA metadata inside the probe scan."""
    db, ccfg = _db(n_docs=800, dim=16, seed=seed)
    prng = np.random.default_rng(seed)
    ix = db.index
    # poison ~25% of member entries + the overflow tail
    poison = prng.random(ix.members.shape) < 0.25
    junk = prng.integers(-5, db.hot_cfg.capacity + 500, ix.members.shape)
    ix.members[poison] = junk[poison]
    ix.overflow = [int(x) for x in
                   prng.integers(-5, db.hot_cfg.capacity + 500, 16)]
    ix._dev = None
    snap = db.log.snapshot()
    tenant_of = np.asarray(snap["tenant"])
    ts_of = np.asarray(snap["updated_at"])
    q = np.asarray(make_queries(ccfg, 1, batch=2, seed=seed))[0]
    min_ts = ccfg.now_ts // 3
    for t in range(ccfg.n_tenants):
        sess = db.session(Principal(tenant_id=t, group_bits=0xFFFFFFFF))
        res = (sess.search(q).newer_than(min_ts).limit(8)
               .using("ivf").run())
        got = res.slots[res.slots >= 0]
        assert (got < db.hot_cfg.capacity).all() and (got >= 0).all()
        assert (tenant_of[got] == t).all(), "poisoned member table leaked"
        assert (ts_of[got] >= min_ts).all()


# ---------------------------------------------------------------------------
# planner: selectivity guard + hint override + explain
# ---------------------------------------------------------------------------

def test_planner_prefers_ivf_when_index_present():
    eng, why = choose_engine(LogicalPlan(k=5), n_rows=1 << 16, has_index=True)
    assert eng == "ivf" and "index present" in why
    # small arena: exact scan is trivially fast, no point probing
    eng, _ = choose_engine(LogicalPlan(k=5), n_rows=1 << 10, has_index=True)
    assert eng == "ref"
    # no index: nothing changes
    eng, _ = choose_engine(LogicalPlan(k=5), n_rows=1 << 16)
    assert eng == "ref"


def test_planner_falls_back_on_selective_predicates(db_stack):
    db, ccfg = db_stack
    q = np.asarray(make_queries(ccfg, 1))[0][0]
    admin_plan = db.admin_session().search(q).limit(5).plan()
    assert admin_plan.engine == "ivf"
    sess = db.session(Principal(tenant_id=1, group_bits=0xFFFFFFFF))
    plan = sess.search(q).limit(5).plan()
    assert plan.engine != "ivf"
    assert "ivf skipped" in plan.engine_reason
    assert "under-fill" in plan.engine_reason
    # recency alone is NOT selective for the guard (hot tier covers it)
    recency = db.admin_session().search(q).newer_than(5).limit(5).plan()
    assert recency.engine == "ivf"
    # the caller hint overrides the guard; isolation still holds
    forced = sess.search(q).limit(8).using("ivf").run()
    tenant_of = np.asarray(db.log.snapshot()["tenant"])
    got = forced.slots[forced.slots >= 0]
    assert (tenant_of[got] == 1).all()


def test_ivf_plan_explain_reports_probe_budget(db_stack):
    db, ccfg = db_stack
    q = np.asarray(make_queries(ccfg, 1))[0][0]
    plan = db.admin_session().search(q).limit(5).plan()
    text = plan.explain()
    assert f"nprobe={plan.nprobe}" in text
    assert "candidate rows" in text and "% of arena" in text
    n_clusters, cap, est = plan.ivf_est
    assert est < 0.25 * plan.n_rows, "probe budget must stay sub-linear"
    assert str(plan.nprobe) in text and plan.nprobe in plan.group_key


def test_using_ivf_without_index_raises():
    db = RagDB(StoreConfig(capacity=256, dim=8))
    from tests.test_core_store import make_batch
    db.ingest(make_batch(np.random.default_rng(0), 8, 8, tenant=0))
    with pytest.raises(ValueError, match="build_index"):
        db.admin_session().search(np.ones(8, np.float32)).using("ivf").plan()


# ---------------------------------------------------------------------------
# rows_scanned audit (the count that catches exact-scan regressions)
# ---------------------------------------------------------------------------

def test_rows_scanned_audits_pruning(db_stack):
    db, ccfg = db_stack
    admin = db.admin_session()
    q = np.asarray(make_queries(ccfg, 1, seed=42))[0][0]
    arena = db.hot_cfg.capacity
    before = db.stats.rows_scanned
    admin.search(q).limit(5).using("ref").run()
    assert db.stats.rows_scanned == before + arena
    before = db.stats.rows_scanned
    res = admin.search(q + 0.01).limit(5).run()       # planner's choice: ivf
    assert res.plan.engine == "ivf"
    scanned = db.stats.rows_scanned - before
    assert 0 < scanned < 0.25 * arena, scanned


def test_tight_recency_bound_never_underfills(db_stack):
    """Recency-only plans stay on ivf, but a bound so tight that qualifying
    rows sit outside the probed clusters must not shrink the k-list: the
    executor's exact-rescan net completes it, bit-identical to ref."""
    db, ccfg = db_stack
    admin = db.admin_session()
    ts = np.asarray(db.log.snapshot()["updated_at"])
    # a bound only ~20 live rows clear — far fewer than any probe covers
    min_ts = int(np.sort(ts)[-20])
    q = np.asarray(make_queries(ccfg, 1, seed=21))[0][0]
    plan = admin.search(q).newer_than(min_ts).limit(10).plan()
    assert plan.engine == "ivf"
    res = admin.search(q).newer_than(min_ts).limit(10).run()
    ref = admin.search(q).newer_than(min_ts).limit(10).using("ref").run()
    assert (res.slots == ref.slots).all()
    assert (res.scores == ref.scores).all()


# ---------------------------------------------------------------------------
# overflow tail: scanned exactly, never dropped
# ---------------------------------------------------------------------------

def test_overflow_rows_stay_in_recall(rng):
    """With a cap far below the biggest cluster, the spill lands in the
    overflow tail. Probing ALL clusters must then equal the exact scan —
    which is only possible if the tail is scanned, not dropped."""
    ccfg = CorpusConfig(n_docs=1000, dim=16, n_tenants=3, n_categories=4)
    from repro.core import TransactionLog, empty, unified_query
    scfg = StoreConfig(capacity=2048, dim=16)
    log = TransactionLog(scfg, empty(scfg))
    log.ingest(make_corpus(ccfg))
    snap = log.snapshot()
    index = build_ivf(snap, IVFConfig(n_clusters=8, cluster_cap=64))
    assert len(index.overflow) > 0
    assert int(index.fill.sum()) + len(index.overflow) == 1000
    from repro.core.ivf import ivf_query
    q = np.asarray(make_queries(ccfg, 1, batch=3, seed=5))[0]
    pred = Predicate(min_ts=ccfg.now_ts // 4)
    s_iv, i_iv = ivf_query(snap, index, jnp.asarray(q), pred, 10,
                           nprobe=index.n_clusters)
    s_ex, i_ex = unified_query(snap, jnp.asarray(q), pred, 10)
    for b in range(3):
        assert set(np.asarray(i_iv)[b].tolist()) == \
            set(np.asarray(i_ex)[b].tolist())


# ---------------------------------------------------------------------------
# maintenance: write-through, drift rebuild, cache exactness
# ---------------------------------------------------------------------------

def test_ingest_and_delete_write_through_to_index(rng):
    db, ccfg = _db(n_docs=1200, dim=16)
    from tests.test_core_store import make_batch
    admin = db.admin_session()
    new = make_batch(rng, 1, 16, tenant=0, start_id=50_000)
    db.ingest(new)
    slot = db.log.slot_of(50_000)
    q = np.asarray(new.emb)[0]
    res = admin.search(q).limit(3).using("ivf").run()
    assert slot == res.slots[0, 0], "fresh row must be probeable immediately"
    db.delete([50_000])
    res2 = admin.search(q).limit(3).using("ivf").run()
    assert slot not in res2.slots[0].tolist()
    # index bookkeeping stays consistent through the churn
    ix = db.index
    assert int(ix.fill.sum()) + len(ix.overflow) == int(
        db.log.snapshot()["n_live"])


def test_drift_threshold_triggers_rebuild(rng):
    db, ccfg = _db(n_docs=600, dim=16,
                   index_cfg=IVFConfig(n_clusters=16,
                                       drift_rebuild_frac=0.05))
    from tests.test_core_store import make_batch
    assert db.index.epoch == 0
    db.ingest(make_batch(rng, 40, 16, tenant=0, start_id=90_000))  # > 5% churn
    assert db.index.epoch == 1, "drift past the threshold must rebuild"
    assert db.index.churn == 0


def test_cache_exact_across_ingest_touching_index(rng):
    db, ccfg = _db(n_docs=1500, dim=16)
    admin = db.admin_session()
    q = np.asarray(make_queries(ccfg, 1, seed=9))[0][0]
    base = admin.search(q).limit(5).run()
    assert base.plan.engine == "ivf"
    assert admin.search(q).limit(5).run().cached
    # ingest a doc embedded AT the query: the probe's answer must change
    from repro.core.store import DocBatch
    db.ingest(DocBatch(
        emb=jnp.asarray(q[None, :]), tenant=jnp.asarray([0]),
        category=jnp.asarray([0]), updated_at=jnp.asarray([ccfg.now_ts]),
        acl=jnp.asarray([0xFFFFFFFF], jnp.uint32),
        doc_id=jnp.asarray([70_000])))
    fresh = admin.search(q).limit(5).run()
    assert not fresh.cached, "post-write hit would be stale"
    assert db.log.slot_of(70_000) == fresh.slots[0, 0]
    # determinism: the same snapshot serves the identical answer again
    again = admin.search(q).limit(5).run()
    assert again.cached and (again.slots == fresh.slots).all()


def test_rebuild_epoch_invalidates_ivf_entries(rng):
    db, ccfg = _db(n_docs=1500, dim=16)
    admin = db.admin_session()
    q = np.asarray(make_queries(ccfg, 1, seed=11))[0][0]
    base = admin.search(q).limit(5).run()
    assert admin.search(q).limit(5).run().cached
    db.build_index(db.index.cfg)          # rebuild: no arena commit, new epoch
    post = admin.search(q).limit(5).run()
    assert not post.cached, "rebuild changes scoring; epoch key must miss"
    # exact-engine entries are epoch-independent and still hit
    ref = admin.search(q).limit(5).using("ref").run()
    assert admin.search(q).limit(5).using("ref").run().cached


def test_device_mirror_patches_in_place(rng):
    """IVF device-mirror granularity (ROADMAP item): a write patches only
    the touched member-table rows on the next probe — upload bytes scale
    with the write, not the (C, cap) table — and the patched mirror stays
    equal to the host truth."""
    from tests.test_core_store import make_batch
    db, ccfg = _db(n_docs=3000, dim=16)
    ix = db.index
    admin = db.admin_session()
    q = np.asarray(make_queries(ccfg, 1, seed=21))[0][0]
    admin.search(q).limit(5).using("ivf").run()          # full upload
    assert ix.mirror_uploads == 1 and ix.mirror_patches == 0
    full_bytes = ix.mirror_bytes_uploaded
    assert full_bytes >= ix.members.nbytes

    db.ingest(make_batch(rng, 2, ccfg.dim, tenant=0, start_id=80_000))
    admin.search(q + 0.01).limit(5).using("ivf").run()   # patched upload
    patch_bytes = ix.mirror_bytes_uploaded - full_bytes
    assert ix.mirror_uploads == 1, "a write must NOT re-upload the mirror"
    assert ix.mirror_patches >= 1
    assert 0 < patch_bytes <= 2 * ix.cluster_cap * 4 + 1024, (
        f"patch uploaded {patch_bytes}B; expected <= the touched rows")
    assert patch_bytes * 4 < ix.members.nbytes, "upload bytes must shrink"
    # the patched mirror is the host truth, bit for bit
    dev = ix.device_arrays()
    assert np.array_equal(np.asarray(dev["members"]), ix.members)
    over = np.asarray(dev["overflow"])
    assert set(over[over >= 0].tolist()) == set(ix.overflow)

    # a delete that touches a member row patches too (swap-with-last)
    victim = int(np.asarray(db.log.snapshot()["doc_id"])[ix.members[
        ix.members >= 0][0]])
    before = ix.mirror_bytes_uploaded
    db.delete([victim])
    admin.search(q + 0.02).limit(5).using("ivf").run()
    assert ix.mirror_uploads == 1
    assert ix.mirror_bytes_uploaded - before < ix.members.nbytes
    dev = ix.device_arrays()
    assert np.array_equal(np.asarray(dev["members"]), ix.members)
