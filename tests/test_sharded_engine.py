"""Sharded-engine tests that run on the SINGLE real device (S=1 mesh, plus
pure-function selection logic). The multi-device behavior — cross-shard
merge, tenant-affine shard skip, collective-payload bound, placement
invariance — runs in subprocesses under tests/test_distributed.py; this file
keeps the engine's contracts in the tier-1 lane:

  * `lex_topk` is EXACTLY the lexicographic (score desc, doc_id asc) top-k,
    including under constructed score ties (the determinism contract's
    selection primitive);
  * `ShardPlacement` routes slots into contiguous per-shard regions and its
    (shard, local) map is consistent both ways;
  * a mesh-built RagDB at S=1 runs the WHOLE sharded path (placement-routed
    allocation, shard-mapped program, per-shard stats, explain lines)
    bit-identically to the reference engine;
  * per-shard slot recycling: deleting a doc returns its slot to the owning
    shard's free list, and the next doc routed to that shard reuses it.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.ragdb import RagDB
from repro.core.query import unified_query_ref
from repro.core.store import DocBatch, ShardPlacement, StoreConfig
from repro.core.tenancy import Principal
from repro.kernels.arena_scan.sharded import INT32_MAX, lex_topk
from repro.kernels.arena_scan.stages import NEG_INF
from repro.launch.mesh import make_mesh


def _lex_oracle(scores: np.ndarray, doc_ids: np.ndarray, k: int):
    """Brute-force lexicographic (score desc, id asc) top-k per row."""
    b, n = scores.shape
    out_s = np.full((b, k), float(NEG_INF), np.float32)
    out_d = np.full((b, k), INT32_MAX, np.int64)
    out_p = np.full((b, k), -1, np.int64)
    for r in range(b):
        order = sorted(range(n), key=lambda j: (-scores[r, j], doc_ids[j]))
        take = order[: min(k, n)]
        out_s[r, : len(take)] = scores[r, take]
        out_d[r, : len(take)] = doc_ids[take]
        out_p[r, : len(take)] = take
    return out_s, out_d, out_p


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("n,k", [(3, 5), (64, 7), (200, 10)])
def test_lex_topk_matches_bruteforce(seed, n, k):
    rng = np.random.default_rng(seed)
    b = 3
    # quantized scores force REAL ties (several columns share a score), and
    # a sprinkle of NEG_INF rows models masked-out entries
    scores = rng.integers(0, 8, (b, n)).astype(np.float32)
    scores[rng.random((b, n)) < 0.2] = float(NEG_INF)
    doc_ids = rng.permutation(10_000)[:n].astype(np.int32)
    s, d, p = (np.asarray(a) for a in lex_topk(
        jnp.asarray(scores), jnp.asarray(doc_ids), k))
    es, ed, ep = _lex_oracle(scores, doc_ids, k)
    np.testing.assert_array_equal(s, es)
    np.testing.assert_array_equal(d, ed)
    np.testing.assert_array_equal(p, ep)


def test_shard_placement_regions_and_routing():
    pl = ShardPlacement(n_shards=4, capacity=32, kind="tenant")
    assert pl.rows_per_shard == 8
    assert pl.region(0) == (0, 8) and pl.region(3) == (24, 32)
    for slot in range(32):
        sh, local = pl.locate(slot)
        assert pl.shard_of_slot(slot) == sh == slot // 8
        assert pl.region(sh)[0] + local == slot
    # tenant placement routes on tenant id; hash placement on doc id
    assert pl.shard_of_doc(6, 123) == 6 % 4
    ph = ShardPlacement(n_shards=4, capacity=32, kind="hash")
    assert ph.shard_of_doc(6, 123) == 123 % 4
    with pytest.raises(ValueError):
        ShardPlacement(n_shards=3, capacity=32)      # 32 % 3 != 0
    with pytest.raises(ValueError):
        ShardPlacement(n_shards=4, capacity=32, kind="roundrobin")


def _mesh_db(n, dim, placement, **kw):
    mesh = make_mesh((1,), ("data",))
    return RagDB(StoreConfig(capacity=n, dim=dim, metric="dot"), mesh=mesh,
                 shard_axes=("data",), placement=placement, **kw)


def _ingest_random(db, rng, n_docs, dim, n_tenants=6):
    emb = rng.standard_normal((n_docs, dim), dtype=np.float32)
    db.ingest(DocBatch(
        emb=jnp.asarray(emb),
        tenant=jnp.asarray(rng.integers(0, n_tenants, n_docs), jnp.int32),
        category=jnp.asarray(rng.integers(0, 4, n_docs), jnp.int32),
        updated_at=jnp.asarray(rng.integers(1, 100, n_docs), jnp.int32),
        acl=jnp.asarray(np.full(n_docs, 1), jnp.uint32),
        doc_id=jnp.arange(n_docs, dtype=jnp.int32)))
    return emb


@pytest.mark.parametrize("placement", ["hash", "tenant"])
def test_sharded_engine_single_shard_matches_ref(rng, placement):
    n, dim, k = 256, 16, 5
    db = _mesh_db(n, dim, placement)
    _ingest_random(db, rng, 200, dim)
    q = rng.standard_normal((dim,), dtype=np.float32)
    b = (db.session(Principal(tenant_id=3, group_bits=0x1))
         .search(q, normalize=False).limit(k).using("sharded"))
    plan = b.plan()
    assert plan.shards == 1 and plan.placement == placement
    assert "sharding:" in plan.explain()
    res = b.run()
    s0, i0 = unified_query_ref(db.log.snapshot(), jnp.asarray(q[None, :]),
                               plan.pred.as_array(), k)
    np.testing.assert_array_equal(res.slots, np.asarray(i0))
    np.testing.assert_array_equal(res.scores, np.asarray(s0))
    assert db.stats.shards_used == 1
    assert db.stats.shard_rows_scanned == [n]
    assert db.stats.rows_scanned == n
    assert "sharded:" in db.explain()


def test_sharded_plan_keys_carry_shards():
    db = _mesh_db(64, 8, "tenant")
    no_mesh = RagDB(StoreConfig(capacity=64, dim=8, metric="dot"))
    q = np.zeros((8,), np.float32)
    p = (db.session(Principal(tenant_id=1, group_bits=1))
         .search(q).limit(3).using("sharded").plan())
    r = (no_mesh.session(Principal(tenant_id=1, group_bits=1))
         .search(q).limit(3).plan())
    assert 1 in p.group_key and "tenant" in p.group_key
    assert p.fuse_key != r.fuse_key
    assert not p.fusable                     # sharded owns its collective


def test_sharded_without_mesh_rejected_at_plan_time():
    db = RagDB(StoreConfig(capacity=16, dim=4))
    b = (db.session(Principal(tenant_id=0, group_bits=1))
         .search(np.zeros(4, np.float32)).using("sharded").limit(2))
    with pytest.raises(ValueError, match="mesh"):
        b.plan()


def test_placement_slot_recycling_stays_in_region(rng):
    """Delete returns slots to the OWNING shard's free list, and the next
    doc routed there reuses them (LIFO) — region membership is an invariant
    of every slot a tenant's docs ever occupy."""
    n, dim = 64, 8
    db = _mesh_db(n, dim, "tenant")
    _ingest_random(db, rng, 40, dim)
    pl = db.log.placement
    assert pl is not None and pl.kind == "tenant"
    snap = db.log.snapshot()
    tenant = np.asarray(snap["tenant"])
    # placement invariant: every live row sits in its tenant's region
    for slot in np.nonzero(tenant >= 0)[0]:
        assert pl.shard_of_doc(int(tenant[slot]), 0) == pl.shard_of_slot(slot)
    # recycle: delete one doc, re-ingest same tenant -> same slot comes back
    victim = int(np.asarray(snap["doc_id"])[np.nonzero(tenant >= 0)[0][0]])
    vslot = db.log.slot_of(victim)
    vtenant = int(tenant[vslot])
    db.delete([victim])
    db.ingest(DocBatch(
        emb=jnp.asarray(rng.standard_normal((1, dim), dtype=np.float32)),
        tenant=jnp.asarray([vtenant], jnp.int32),
        category=jnp.asarray([0], jnp.int32),
        updated_at=jnp.asarray([50], jnp.int32),
        acl=jnp.asarray([1], jnp.uint32),
        doc_id=jnp.asarray([9999], jnp.int32)))
    assert db.log.slot_of(9999) == vslot


def test_sharded_region_full_is_loud(rng):
    """A shard whose region fills raises instead of spilling into another
    shard's rows (spilling would silently break the affine audit)."""
    db = _mesh_db(8, 4, "tenant")        # S=1: one region of 8 rows
    with pytest.raises(RuntimeError, match="region full"):
        _ingest_random(db, rng, 9, 4, n_tenants=2)
