"""The fused multi-predicate grouped scan: scan once, answer every group.

Acceptance contracts (ISSUE 4):
  * the grouped_topk Pallas kernel (interpret mode) is BIT-identical to the
    jnp ref, which is BIT-identical to the per-group loop it replaces —
    across bucket boundaries and G in {1, 2, 7, 16};
  * the fused executor path (`db.execute` with planner fusion) returns
    scores/slots/tiers bit-identical to the per-group loop, while streaming
    the arena ONCE (`rows_scanned == N`, not G*N) in ONE device program;
  * CROSS-GROUP LEAKAGE IMPOSSIBILITY: a row failing group g's predicate can
    never appear in a g-row's k-list, even when it passes another group's
    predicate in the same fused scan — the kernel-level multi-tenant
    isolation claim, attacked adversarially on a seed grid;
  * `planner.fuse_batch` fuses exactly the exact-engine groups sharing a
    fuse key, and `fuse_min_groups` disables it.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RagDB, fuse_batch
from repro.api import executor as executor_mod
from repro.api.plan import LogicalPlan, PhysicalPlan
from repro.api.planner import CostModel, PlannerConfig
from repro.core import (Predicate, Principal, StoreConfig,
                        unified_query_grouped, unified_query_ref)
from repro.core.query import BLOCK_ALL, stack_predicates
from repro.data.corpus import DAY_S, CorpusConfig, make_corpus
from repro.kernels.grouped_topk.ops import grouped_topk

pytestmark = [pytest.mark.kernels]

GROUP_COUNTS = (1, 2, 7, 16)


def _arena(rng, n, d=16, n_tenants=6):
    return {
        "emb": jnp.asarray(rng.standard_normal((n, d)).astype(np.float32)),
        "tenant": jnp.asarray(rng.integers(-1, n_tenants, n, dtype=np.int32)),
        "updated_at": jnp.asarray(rng.integers(0, 1000, n, dtype=np.int32)),
        "category": jnp.asarray(rng.integers(0, 8, n, dtype=np.int32)),
        "acl": jnp.asarray(rng.integers(1, 16, n, dtype=np.int64)
                           .astype(np.uint32)),
    }


def _preds(rng, g):
    return [Predicate(tenant=int(rng.integers(-2, 6)),
                      min_ts=int(rng.integers(0, 600)),
                      cat_mask=int(rng.integers(1, 2 ** 32)),
                      acl_bits=int(rng.integers(1, 16)))
            for _ in range(g)]


# ---------------------------------------------------------------------------
# kernel / ref / per-group loop bit-identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,N,D,k,blk_n", [
    (8, 1000, 96, 10, 512),    # N not a block multiple -> padding path
    (3, 513, 64, 8, 256),      # odd everything
    (16, 2048, 128, 5, 512),
    (1, 64, 8, 4, 64),         # tiny arena, B=1
])
@pytest.mark.parametrize("G", GROUP_COUNTS)
def test_kernel_bit_identical_to_ref(B, N, D, k, blk_n, G, rng):
    """Pallas kernel body (interpret mode on CPU) vs jnp ref: every score
    and slot bit-equal, for every group count."""
    store = _arena(rng, N, D)
    q = rng.standard_normal((B, D)).astype(np.float32)
    preds = stack_predicates(_preds(rng, G))
    gids = rng.integers(0, G, B).astype(np.int32)
    args = (q, store["emb"], store["tenant"], store["updated_at"],
            store["category"], store["acl"], gids, preds, k)
    s_r, i_r = grouped_topk(*args, use_kernel=False)
    s_k, i_k = grouped_topk(*args, use_kernel=True, interpret=True,
                            blk_n=blk_n)
    assert (np.asarray(s_r) == np.asarray(s_k)).all()
    assert (np.asarray(i_r) == np.asarray(i_k)).all()


@pytest.mark.parametrize("G", GROUP_COUNTS)
def test_grouped_ref_bit_identical_to_pergroup_loop(G, rng):
    """The fused scan is a pure batching transform: per query row it returns
    exactly what the per-group exact scan returns for that row's predicate."""
    store = _arena(rng, 700, 24)
    B, k = 9, 6
    q = rng.standard_normal((B, 24)).astype(np.float32)
    preds = _preds(rng, G)
    gids = rng.integers(0, G, B).astype(np.int32)
    s_g, i_g = unified_query_grouped(store, jnp.asarray(q), gids, preds, k)
    s_g, i_g = np.asarray(s_g), np.asarray(i_g)
    for b in range(B):
        s1, i1 = unified_query_ref(store, jnp.asarray(q[b:b + 1]),
                                   preds[int(gids[b])].as_array(), k)
        assert (np.asarray(s1)[0] == s_g[b]).all()
        assert (np.asarray(i1)[0] == i_g[b]).all()


def test_blocker_padding_groups_mask_everything(rng):
    """pow2 G-padding uses BLOCK_ALL rows: a blocker group returns nothing,
    and its presence cannot perturb real groups (shape-reuse safety)."""
    store = _arena(rng, 300, 16)
    q = rng.standard_normal((4, 16)).astype(np.float32)
    preds = _preds(rng, 3)
    gids = np.asarray([0, 1, 2, 0], np.int32)
    s0, i0 = unified_query_grouped(store, jnp.asarray(q), gids, preds, 5)
    s1, i1 = unified_query_grouped(store, jnp.asarray(q), gids,
                                   preds + [BLOCK_ALL], 5)
    assert (np.asarray(s0) == np.asarray(s1)).all()
    assert (np.asarray(i0) == np.asarray(i1)).all()
    # a row pointed AT the blocker group sees an empty arena
    s2, i2 = unified_query_grouped(store, jnp.asarray(q),
                                   np.asarray([3, 3, 3, 3], np.int32),
                                   preds + [BLOCK_ALL], 5)
    assert (np.asarray(i2) == -1).all()


# ---------------------------------------------------------------------------
# fused executor path: bit-identity + the G*N -> N bandwidth audit
# ---------------------------------------------------------------------------

def _db(tiered: bool):
    ccfg = CorpusConfig(n_docs=1200, dim=16, n_tenants=16, n_categories=4)
    scfg = StoreConfig(capacity=2048, dim=16)
    if tiered:
        db = RagDB(scfg, warm_cfg=scfg, hot_window_s=90 * DAY_S,
                   now_ts=ccfg.now_ts, result_cache_size=0)
    else:
        db = RagDB(scfg, result_cache_size=0)
    db.ingest(make_corpus(ccfg))
    return db, ccfg


def _plans(db, ccfg, rng, G, B_total, k=5):
    """B_total query rows spread unevenly over G tenant groups (so fused
    row spans cross bucket boundaries)."""
    plans = []
    for i in range(B_total):
        sess = db.session(Principal(tenant_id=i % G, group_bits=0xFFFFFFFF))
        q = rng.standard_normal(ccfg.dim).astype(np.float32)
        plans.append(sess.search(q).limit(k).plan())
    return plans


@pytest.mark.parametrize("G", GROUP_COUNTS)
@pytest.mark.parametrize("B_total", [7, 8, 9])   # bucket boundary 8
def test_fused_execute_bit_identical_and_scans_once(G, B_total, rng):
    db, ccfg = _db(tiered=False)
    G = min(G, B_total)
    arena = db.log.snapshot()["emb"].shape[0]

    rng_a = np.random.default_rng(11)
    plans_f = _plans(db, ccfg, rng_a, G, B_total)
    rows0, calls0, scans0 = (db.stats.rows_scanned, db.stats.device_calls,
                             db.stats.fused_scans)
    fs, fi, ft = db.execute(plans_f, use_cache=False)
    fused_rows = db.stats.rows_scanned - rows0
    fused_calls = db.stats.device_calls - calls0

    db.planner_cfg = dataclasses.replace(db.planner_cfg,
                                         fuse_min_groups=1 << 30)
    rng_b = np.random.default_rng(11)
    plans_l = _plans(db, ccfg, rng_b, G, B_total)
    rows1, calls1 = db.stats.rows_scanned, db.stats.device_calls
    ls, li, lt = db.execute(plans_l, use_cache=False)
    db.planner_cfg = PlannerConfig()

    assert (fs == ls).all() and (fi == li).all() and (ft == lt).all()
    assert db.stats.rows_scanned - rows1 == G * arena        # the loop: G*N
    assert db.stats.device_calls - calls1 == G
    if G >= 2:
        assert fused_rows == arena, "fused call must stream the arena ONCE"
        assert fused_calls == 1
        assert db.stats.fused_scans == scans0 + 1
    else:
        assert fused_rows == arena and fused_calls == 1      # nothing to fuse


def test_fused_execute_tiered_merge_bit_identical(rng):
    """hot+warm groups fuse too: the hot scan fuses, the per-group warm
    probes and merges stay exact — results identical to the loop."""
    db, ccfg = _db(tiered=True)
    rng_a = np.random.default_rng(5)
    plans_f = _plans(db, ccfg, rng_a, 3, 8)
    assert all(p.route == "hot+warm" for p in plans_f)
    warm0 = db.stats.warm_queries
    fs, fi, ft = db.execute(plans_f, use_cache=False)
    assert db.stats.warm_queries - warm0 == 8     # every row probed warm
    db.planner_cfg = dataclasses.replace(db.planner_cfg,
                                         fuse_min_groups=1 << 30)
    rng_b = np.random.default_rng(5)
    ls, li, lt = db.execute(_plans(db, ccfg, rng_b, 3, 8), use_cache=False)
    db.planner_cfg = PlannerConfig()
    assert (fs == ls).all() and (fi == li).all() and (ft == lt).all()
    assert (ft == 1).any(), "warm tier must contribute rows to the merge"


# ---------------------------------------------------------------------------
# cross-group leakage impossibility (seed grid, adversarial)
# ---------------------------------------------------------------------------

def _oracle_mask(store, pred):
    tenant = np.asarray(store["tenant"])
    ts = np.asarray(store["updated_at"])
    cat = np.asarray(store["category"])
    acl = np.asarray(store["acl"])
    mask = (tenant >= 0) & (ts >= pred.min_ts)
    if pred.tenant != -2:
        mask &= tenant == pred.tenant
    mask &= ((np.uint64(1) << (cat.astype(np.uint64) & np.uint64(31)))
             & np.uint64(pred.cat_mask)) != 0
    mask &= (acl & np.uint32(pred.acl_bits)) != 0
    return mask


@pytest.mark.parametrize("seed", range(25))
@pytest.mark.parametrize("use_kernel", [False, True])
def test_cross_group_leakage_impossible(seed, use_kernel):
    """For ANY corpus and ANY stacked predicate set: no row returned to a
    g-row violates group g's predicate — even rows that PASS another group's
    predicate in the same fused scan (every group here shares the arena, so
    cross-qualifying rows are abundant by construction)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(64, 400))
    G = int(rng.integers(2, 9))
    B = int(rng.integers(G, 2 * G + 4))
    k = int(rng.integers(1, 12))
    store = _arena(rng, n)
    # adversarial predicate set: per-tenant groups (every live row qualifies
    # SOMEWHERE, so any leak has a donor group) + random extra clauses
    preds = [Predicate(tenant=g % 6, min_ts=int(rng.integers(0, 400)),
                       acl_bits=int(rng.integers(1, 16)))
             for g in range(G)]
    gids = rng.integers(0, G, B).astype(np.int32)
    q = rng.standard_normal((B, 16)).astype(np.float32)
    s, slots = grouped_topk(q, store["emb"], store["tenant"],
                            store["updated_at"], store["category"],
                            store["acl"], gids, stack_predicates(preds), k,
                            use_kernel=use_kernel,
                            interpret=use_kernel or None, blk_n=64)
    slots = np.asarray(slots)
    masks = [_oracle_mask(store, p) for p in preds]
    for b in range(B):
        got = slots[b][slots[b] >= 0]
        assert masks[int(gids[b])][got].all(), (
            f"LEAK: row {b} (group {int(gids[b])}) returned a slot that "
            f"violates its own group's predicate")
        # exactly min(k, qualifying) rows returned — no under-fill either
        assert len(got) == min(k, int(masks[int(gids[b])].sum()))


# ---------------------------------------------------------------------------
# pow2 padding: blocker lanes carry k=0 semantics (regression, ISSUE 5)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("G,B", [
    (3, 7),    # rows pad 7 -> 8, groups pad 3 -> 4 (one blocker lane)
    (4, 7),    # groups already pow2: a blocker bucket must OPEN for the
               # padding rows (4 -> 8) instead of borrowing group 0
    (3, 8),    # rows exactly at the bucket: no row padding, blocker unused
    (5, 9),    # both sides pad across a boundary
])
def test_padding_rows_ride_blocker_lanes(G, B, rng):
    """Bucket-padding query rows used to inherit group 0's predicate (and
    its k-list): they scored real rows under a real group's predicate and
    allocated k result rows each. They must instead point at a BLOCK_ALL
    blocker lane — k=0 semantics: the executor asserts their k-lists come
    back empty, `ExecStats.padded_groups` audits the lanes, and the real
    rows' results are bit-identical with and without the padding."""
    from repro.api.executor import run_grouped_fused
    store = _arena(rng, 640, 16)
    snap = dict(store)
    q = rng.standard_normal((B, 16)).astype(np.float32)
    uniq = _preds(rng, G)
    preds = [uniq[i % G] for i in range(B)]
    st_pad, st_raw = executor_mod.ExecStats(), executor_mod.ExecStats()
    shapes = executor_mod.CompiledShapes()
    s_p, i_p, _ = run_grouped_fused(snap, q, preds, 5, stats=st_pad,
                                    shapes=shapes)   # bucketed launch
    s_r, i_r, _ = run_grouped_fused(snap, q, preds, 5, stats=st_raw)
    assert (s_p == s_r).all() and (i_p == i_r).all()
    g_uniq = len(set(preds))
    bucket = executor_mod.bucket_rows(B)
    if bucket > B:
        # padding rows exist: at least one blocker lane must exist too,
        # even when the group count was already a power of two
        assert st_pad.padded_groups >= 1
        assert st_pad.padded_rows == bucket - B
    g_bucket = executor_mod.bucket_rows(
        g_uniq + (1 if bucket > B and
                  executor_mod.bucket_rows(g_uniq) == g_uniq else 0))
    assert st_pad.padded_groups == g_bucket - g_uniq
    # the unbucketed launch still pow2-pads the predicate stack only
    assert st_raw.padded_rows == 0


def test_blocker_lane_rows_allocate_no_results(rng):
    """Direct audit of the finish-time assertion: a launch whose padding
    rows point at the blocker lane returns all-empty k-lists for them."""
    from repro.api.executor import (CompiledShapes, ExecStats, _finish_hot,
                                    _launch_grouped)
    store = _arena(rng, 512, 16)
    q = rng.standard_normal((5, 16)).astype(np.float32)   # pads to 8
    preds = _preds(rng, 4)                                # pow2 already
    gids = np.asarray([0, 1, 2, 3, 0], np.int32)
    hot = _launch_grouped(dict(store), q, gids, preds, 6, "ref",
                          stats=ExecStats(), shapes=CompiledShapes())
    s, sl = _finish_hot(hot)    # would assert on a blocker-lane leak
    assert sl.shape[0] == 8
    assert (sl[5:] == -1).all()
    assert (sl[:5] >= -1).any()

def _plan(t=0, k=5, engine="ref", route="hot", n_rows=1024):
    lp = LogicalPlan(tenant=t, k=k)
    return PhysicalPlan(logical=lp, pred=lp.predicate(), engine=engine,
                        engine_reason="", route=route, route_reason="",
                        n_rows=n_rows)


def test_fuse_batch_rules():
    # 3 exact groups sharing (k, engine, route): one fused unit
    units = fuse_batch([_plan(0), _plan(1), _plan(2)])
    assert [u.fused for u in units] == [True]
    assert len(units[0].plans) == 3
    # different k never fuses together
    units = fuse_batch([_plan(0, k=5), _plan(1, k=5), _plan(2, k=7)])
    assert sorted((u.fused, len(u.plans)) for u in units) == [
        (False, 1), (True, 2)]
    # different route never fuses together
    units = fuse_batch([_plan(0, route="hot"), _plan(1, route="hot+warm")])
    assert all(not u.fused for u in units)
    # ivf / sharded stay on their engines
    units = fuse_batch([_plan(0, engine="ivf"), _plan(1, engine="ivf"),
                        _plan(2), _plan(3)])
    flags = [(u.fused, u.plans[0].engine) for u in units]
    assert (False, "ivf") in flags and (True, "ref") in flags
    # fuse_min_groups disables
    units = fuse_batch([_plan(0), _plan(1)],
                       cfg=PlannerConfig(fuse_min_groups=3))
    assert all(not u.fused and "fuse_min_groups" in u.reason for u in units)
    # single group: nothing to fuse
    assert [u.fused for u in fuse_batch([_plan(0)])] == [False]


def test_fuse_batch_priced_by_cost_model():
    cm = CostModel(curves=(("ref", ((1 << 10, 1.0), (1 << 20, 1000.0))),))
    units = fuse_batch([_plan(0), _plan(1)],
                       cfg=PlannerConfig(cost_model=cm))
    assert units[0].fused and "cost model" in units[0].reason
    assert "2 looped scans" in units[0].reason


def test_explain_surfaces_fusion(rng):
    db, ccfg = _db(tiered=False)
    plans = _plans(db, ccfg, rng, 3, 6)
    assert "fusion:    eligible" in plans[0].explain()
    db.execute(plans, use_cache=False)
    text = db.explain()
    assert "grouped scan: fused 3 groups -> 1 scans" in text
    ivf_plan = _plan(engine="ivf")
    assert "not eligible" in ivf_plan.explain()
