"""The front door: builder -> plan -> grouped execution.

Acceptance contract of the API redesign:
  * a builder-API query returns results bit-identical to the equivalent
    direct `unified_query_ref` call;
  * `explain()` reports the chosen engine and tier route;
  * `RAGEngine.serve` through the front door fuses the batch's exact-engine
    predicate groups into ONE grouped scan (the raw-store compat path still
    issues one call per group) — counted by monkeypatching the executor's
    two dispatch points;
  * tier routing decisions match the paper's §7.3 invariant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import LogicalPlan, RagDB
from repro.api import executor as executor_mod
from repro.api.plan import logical_from_predicate
from repro.api.planner import PlannerConfig, choose_engine, choose_route
from repro.core import Predicate, Principal, StoreConfig, unified_query_ref
from repro.data.corpus import DAY_S, CorpusConfig, make_corpus
from repro.models.transformer import TransformerConfig, init
from repro.serving.engine import RAGEngine, Request


@pytest.fixture(scope="module")
def db_stack():
    ccfg = CorpusConfig(n_docs=2500, dim=24, n_tenants=5, n_categories=4)
    db = RagDB(StoreConfig(capacity=4096, dim=24))
    corpus = make_corpus(ccfg)
    db.ingest(corpus)
    return db, corpus, ccfg


CHAINS = [
    lambda s, ccfg: s.search,                                       # similarity only
    lambda s, ccfg: lambda q: s.search(q).newer_than(ccfg.now_ts - 90 * DAY_S),
    lambda s, ccfg: lambda q: s.search(q).in_categories([0, 2]),
    lambda s, ccfg: lambda q: (s.search(q).newer_than(ccfg.now_ts - 30 * DAY_S)
                               .in_categories([1, 2, 3])),
]


@pytest.mark.parametrize("chain_i", range(len(CHAINS)))
def test_builder_bit_identical_to_ref(db_stack, chain_i, rng):
    db, corpus, ccfg = db_stack
    sess = db.session(Principal(tenant_id=2, group_bits=0b0101))
    q = rng.standard_normal((3, ccfg.dim)).astype(np.float32)
    builder = CHAINS[chain_i](sess, ccfg)(q).limit(6)
    res = builder.run()
    # the equivalent direct call: same lowered predicate, same normalized q
    qn = q / np.maximum(np.linalg.norm(q, axis=1, keepdims=True), 1e-12)
    pred = builder.lower().predicate()
    s, sl = unified_query_ref(db.log.snapshot(), jnp.asarray(qn),
                              pred.as_array(), 6)
    assert (np.asarray(sl) == res.slots).all()
    assert (np.asarray(s) == res.scores).all()
    assert (res.tiers == 0).all()


def test_session_cannot_name_a_tenant(db_stack):
    db, _, _ = db_stack
    sess = db.session(Principal(tenant_id=1, group_bits=0xFFFFFFFF))
    builder = sess.search(np.zeros(24, np.float32))
    # no builder method can touch the tenant/ACL clauses...
    assert not any(hasattr(builder, m) for m in
                   ("tenant", "in_tenant", "for_tenant", "acl", "with_acl"))
    # ...and the lowered plan carries the principal's clauses verbatim
    lp = builder.newer_than(5).in_categories([1]).limit(3).lower()
    assert lp.tenant == 1 and lp.acl_bits == 0xFFFFFFFF


def test_explain_reports_engine_and_route(db_stack):
    db, _, ccfg = db_stack
    sess = db.session(Principal(tenant_id=0, group_bits=0xFFFFFFFF))
    text = (sess.search(np.zeros(ccfg.dim, np.float32))
            .newer_than(ccfg.now_ts - 10 * DAY_S).limit(4).explain())
    assert "engine:" in text and "ref" in text
    assert "route:" in text and "hot" in text
    assert "tenant = 0" in text


def test_planner_engine_rules():
    lp = LogicalPlan(k=5)
    cfg = PlannerConfig(pallas_min_rows=1 << 15, shard_min_rows=1 << 20)
    eng, _ = choose_engine(lp, n_rows=1 << 12, cfg=cfg)
    assert eng == ("ref" if jax.default_backend() != "tpu" else "ref")
    eng, why = choose_engine(lp, n_rows=1 << 21, cfg=cfg, has_mesh=True)
    assert eng == "sharded" and "mesh" in why
    hint, _ = choose_engine(LogicalPlan(k=5, engine="pallas"), n_rows=8, cfg=cfg)
    assert hint == "pallas"


def test_planner_route_rules():
    window, now = 100, 1000
    constrained_recent = LogicalPlan(tenant=1, min_ts=950, k=3)
    unconstrained = LogicalPlan(k=3)
    constrained_old = LogicalPlan(tenant=1, min_ts=0, k=3)
    route, _ = choose_route(constrained_recent, hot_window_s=window,
                            now_ts=now, warm_rows=10)
    assert route == "hot"
    route, _ = choose_route(unconstrained, hot_window_s=window, now_ts=now,
                            warm_rows=10)
    assert route == "hot+warm"
    route, _ = choose_route(constrained_old, hot_window_s=window, now_ts=now,
                            warm_rows=10)
    assert route == "hot+warm"
    # empty warm tier never probed
    route, why = choose_route(unconstrained, hot_window_s=window, now_ts=now,
                              warm_rows=0)
    assert route == "hot" and "empty" in why


def test_logical_from_predicate_roundtrip():
    pred = Predicate(tenant=3, min_ts=77, cat_mask=0b1010, acl_bits=0b11)
    lp = logical_from_predicate(pred, k=5)
    assert lp.predicate() == pred
    assert lp.constrained
    assert logical_from_predicate(Predicate(), k=5).predicate() == Predicate()


def _count_calls(monkeypatch):
    """Counts both executor dispatch points: per-group scans
    (`unified_query`) and fused grouped scans (`unified_query_grouped`)."""
    calls = {"n": 0, "grouped": 0}
    real = executor_mod.unified_query
    real_grouped = executor_mod.unified_query_grouped

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    def counting_grouped(*args, **kwargs):
        calls["grouped"] += 1
        return real_grouped(*args, **kwargs)

    monkeypatch.setattr(executor_mod, "unified_query", counting)
    monkeypatch.setattr(executor_mod, "unified_query_grouped", counting_grouped)
    return calls


def _mini_engine(store_or_db, ccfg, k=3):
    cfg = TransformerConfig(name="gen", n_layers=1, d_model=16, n_heads=2,
                            n_kv_heads=2, d_ff=32, vocab_size=64,
                            dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    return RAGEngine(store_or_db, cfg, params, k=k, max_prompt=16, max_len=24)


def _requests(rng, ccfg, tenants):
    return [Request(principal=Principal(tenant_id=t, group_bits=0xFFFFFFFF),
                    query_emb=rng.standard_normal(ccfg.dim).astype(np.float32),
                    prompt_tokens=np.asarray([3, 4], np.int32),
                    min_ts=ccfg.now_ts - 150 * DAY_S, max_new_tokens=2)
            for t in tenants]


@pytest.mark.parametrize("front_door", [False, True])
def test_serve_batches_by_predicate_group(db_stack, rng, monkeypatch,
                                          front_door):
    db, corpus, ccfg = db_stack
    engine = _mini_engine(db if front_door else db.log.snapshot(), ccfg)
    # 8 requests, 3 unique predicate groups (tenants 0/1/2 repeated)
    tenants = [0, 1, 2, 0, 1, 2, 0, 1]
    reqs = _requests(rng, ccfg, tenants)
    calls = _count_calls(monkeypatch)
    rows0 = db.stats.rows_scanned
    fused0 = db.stats.fused_scans
    resps = engine.serve(reqs)
    arena = db.log.snapshot()["emb"].shape[0]
    if front_door:
        # the 3 exact-engine groups share (k, engine, route) -> the planner
        # fuses them into ONE grouped scan: 1 device call, and the arena is
        # streamed ONCE (rows_scanned == N, not 3*N) — the bandwidth
        # regression guard, by count
        assert (calls["n"], calls["grouped"]) == (0, 1), calls
        assert engine.last_retrieval_device_calls == 1
        assert db.stats.rows_scanned - rows0 == arena
        assert db.stats.fused_scans == fused0 + 1
    else:
        # raw-store compat path: still one per-group call each
        assert (calls["n"], calls["grouped"]) == (3, 0), calls
        assert engine.last_retrieval_device_calls == 3
    # grouped execution preserves per-request isolation and ordering
    tenant_of = np.asarray(corpus.tenant)
    for t, r in zip(tenants, resps):
        got = r.doc_slots[r.doc_slots >= 0]
        assert len(got) > 0 and (tenant_of[got] == t).all()


def test_grouped_matches_looped(db_stack, rng, monkeypatch):
    """Grouped execution is a pure batching transform: results identical to
    issuing each request's query alone."""
    db, _, ccfg = db_stack
    snap = db.log.snapshot()
    q = rng.standard_normal((6, ccfg.dim)).astype(np.float32)
    preds = [Predicate(tenant=i % 2) for i in range(6)]
    stats = executor_mod.ExecStats()
    gs, gi, n_calls = executor_mod.run_grouped(snap, q, preds, 4, stats=stats)
    assert n_calls == 2
    assert stats.rows_scanned == 2 * snap["emb"].shape[0]
    for i, p in enumerate(preds):
        s, sl = unified_query_ref(snap, jnp.asarray(q[i:i + 1]), p.as_array(), 4)
        assert (np.asarray(sl)[0] == gi[i]).all()
        assert (np.asarray(s)[0] == gs[i]).all()


def test_tiered_db_merges_and_routes(rng):
    ccfg = CorpusConfig(n_docs=900, dim=16, n_tenants=4)
    scfg = StoreConfig(capacity=2048, dim=16)
    db = RagDB(scfg, warm_cfg=scfg, hot_window_s=90 * DAY_S, now_ts=ccfg.now_ts)
    db.ingest(make_corpus(ccfg))
    assert 0 < int(db.log.snapshot()["n_live"]) < 900
    assert db.router.warm.n_docs > 0
    sess = db.session(Principal(tenant_id=1, group_bits=0xFFFFFFFF))
    q = rng.standard_normal(16).astype(np.float32)
    # constrained + recent: hot only
    res = sess.search(q).newer_than(ccfg.now_ts - 60 * DAY_S).limit(4).run()
    assert res.plan.route == "hot"
    assert (res.tiers[res.slots >= 0] == 0).all()
    # long-tail similarity from the admin surface: merges both tiers
    res2 = db.admin_session().search(q).limit(6).run()
    assert res2.plan.route == "hot+warm"
    assert db.stats.warm_queries == 1


def test_quota_charged_through_ingest(rng):
    db = RagDB(StoreConfig(capacity=64, dim=8))
    tid = db.create_tenant(quota=4)
    from tests.test_core_store import make_batch
    db.ingest(make_batch(rng, 3, 8, tenant=tid))
    with pytest.raises(PermissionError):
        db.ingest(make_batch(rng, 2, 8, tenant=tid, start_id=10))
    # a rejected batch must not leave a partial charge or partial write
    assert db.tenants.doc_count[tid] == 3
    assert int(db.log.snapshot()["n_live"]) == 3
    db.ingest(make_batch(rng, 1, 8, tenant=tid, start_id=20))   # still room


def test_quota_refunded_on_delete(rng):
    db = RagDB(StoreConfig(capacity=64, dim=8))
    tid = db.create_tenant(quota=4)
    from tests.test_core_store import make_batch
    db.ingest(make_batch(rng, 4, 8, tenant=tid))
    db.delete([0, 1, 2, 3])
    assert db.tenants.doc_count[tid] == 0
    db.ingest(make_batch(rng, 4, 8, tenant=tid, start_id=10))   # churn works
    assert db.tenants.doc_count[tid] == 4


def test_tiered_requires_hot_window():
    scfg = StoreConfig(capacity=64, dim=8)
    with pytest.raises(ValueError, match="hot_window_s"):
        RagDB(scfg, warm_cfg=scfg)


def test_group_key_separates_routes(db_stack):
    """Same lowered predicate, different route, must not share a group:
    in_categories(range(32)) lowers to the pass-all mask yet is constrained."""
    db, _, ccfg = db_stack
    admin = db.admin_session()
    q = np.zeros(ccfg.dim, np.float32)
    p1 = admin.search(q).limit(4).plan()
    p2 = admin.search(q).in_categories(range(32)).limit(4).plan()
    assert p1.pred == p2.pred
    if p1.route != p2.route:
        assert p1.group_key != p2.group_key
    # route is always part of the key
    assert p1.route in p1.group_key and p2.route in p2.group_key


def test_tiered_writes_reach_warm_docs(rng):
    """The write facade is tier-aware: update/delete work on documents the
    router placed in the warm tier."""
    ccfg = CorpusConfig(n_docs=400, dim=16, n_tenants=3)
    scfg = StoreConfig(capacity=1024, dim=16)
    db = RagDB(scfg, warm_cfg=scfg, hot_window_s=90 * DAY_S, now_ts=ccfg.now_ts)
    corpus = make_corpus(ccfg)
    db.ingest(corpus)
    ts = np.asarray(corpus.updated_at)
    order = np.argsort(ts)
    warm_doc = int(np.asarray(corpus.doc_id)[order[0]])   # oldest -> warm
    warm_doc2 = int(np.asarray(corpus.doc_id)[order[1]])
    hot_doc = int(np.asarray(corpus.doc_id)[order[-1]])
    assert not db.log.has_doc(warm_doc) and db.log.has_doc(hot_doc)
    # update both in one call: a fresh timestamp PROMOTES the warm doc to
    # hot (recency-constrained queries are hot-only, so it must move)
    db.update([warm_doc, hot_doc],
              rng.standard_normal((2, 16)).astype(np.float32),
              [ccfg.now_ts, ccfg.now_ts])
    assert db.log.has_doc(warm_doc) and not db.router.warm.has_doc(warm_doc)
    # the promoted doc is now visible to a recency-filtered session query
    sess = db.session(Principal(
        tenant_id=int(np.asarray(corpus.tenant)[order[0]]),
        group_bits=0xFFFFFFFF))
    snap_emb = np.asarray(db.log.snapshot()["emb"])[db.log.slot_of(warm_doc)]
    res = (sess.search(snap_emb, normalize=False)
           .newer_than(ccfg.now_ts - 10 * DAY_S).limit(4).run())
    assert db.log.slot_of(warm_doc) in res.slots[0].tolist()
    # an update keeping an old timestamp stays in the warm tier
    db.update([warm_doc2], rng.standard_normal((1, 16)).astype(np.float32),
              [int(ts[order[1]])])
    assert db.router.warm.has_doc(warm_doc2)
    # delete a warm doc: no KeyError, row invisible afterwards
    wslot = db.router.warm.slot_of(warm_doc2)
    db.delete([warm_doc2])
    assert not db.router.warm.has_doc(warm_doc2)
    assert not bool(np.asarray(db.router.warm.valid)[wslot])


def test_sharded_hint_without_mesh_raises_cleanly(db_stack):
    db, _, ccfg = db_stack
    with pytest.raises(ValueError, match="mesh"):
        (db.admin_session().search(np.ones(ccfg.dim, np.float32))
         .using("sharded").limit(3).run())


def test_single_tier_db_warm_arena_is_tiny():
    db = RagDB(StoreConfig(capacity=1 << 12, dim=32))
    # single-tier mode must not duplicate the hot arena for the unused warm client
    assert db.router.warm.emb.shape[0] == 1


def test_serve_reports_tiers_and_skips_warm_in_prompts(rng):
    """Tiered serving: warm-tier slots index a different arena, so they feed
    provenance (doc_tiers) but never doc_token_fn."""
    ccfg = CorpusConfig(n_docs=600, dim=16, n_tenants=3)
    scfg = StoreConfig(capacity=1024, dim=16)
    db = RagDB(scfg, warm_cfg=scfg, hot_window_s=90 * DAY_S, now_ts=ccfg.now_ts)
    db.ingest(make_corpus(ccfg))
    seen_hot_slots = []
    engine = _mini_engine(db, ccfg)
    engine.doc_token_fn = lambda s: (seen_hot_slots.append(s),
                                     np.asarray([s % 60], np.int32))[1]
    # min_ts=0 -> route hot+warm: responses may carry warm slots
    reqs = [Request(principal=Principal(tenant_id=0, group_bits=0xFFFFFFFF),
                    query_emb=rng.standard_normal(ccfg.dim).astype(np.float32),
                    prompt_tokens=np.asarray([1], np.int32), max_new_tokens=2)]
    (resp,) = engine.serve(reqs)
    assert resp.doc_tiers is not None
    hot_slots = resp.doc_slots[(resp.doc_slots >= 0) & (resp.doc_tiers == 0)]
    assert sorted(seen_hot_slots) == sorted(hot_slots.tolist())
