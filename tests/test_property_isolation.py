"""Property tests on the system's central invariants.

1. LEAKAGE-IMPOSSIBILITY: for ANY corpus, ANY predicate, ANY query, no row
   returned by the unified engine violates the predicate (the paper's
   row-level-security claim, attacked adversarially).
2. TOP-K SOUNDNESS: returned scores are the true top-k of the masked score
   vector, in non-increasing order.
3. The filtered_topk Pallas kernel satisfies the same contract as the ref.
4. FRONT DOOR: the same two properties hold through `RagDB`/`Session` — a
   builder chain is bit-identical to the direct reference call, and no
   Session can surface another tenant's rows (the API cannot even express
   the request).

Runs under Hypothesis when installed; otherwise the same checks sweep a
deterministic seed grid so the invariants stay enforced on minimal CI rigs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RagDB
from repro.core import Principal, StoreConfig
from repro.core.query import Predicate, unified_query_ref
from repro.core.store import DocBatch
from repro.kernels.filtered_topk.ops import filtered_topk

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def _store_from(emb, tenant, ts, cat, acl):
    n = emb.shape[0]
    return {
        "emb": jnp.asarray(emb), "tenant": jnp.asarray(tenant),
        "category": jnp.asarray(cat), "updated_at": jnp.asarray(ts),
        "acl": jnp.asarray(acl, jnp.uint32),
        "doc_id": jnp.arange(n, dtype=jnp.int32),
        "version": jnp.zeros(n, jnp.int32),
        "commit_ts": jnp.int32(1), "n_live": jnp.int32(n),
    }


def _args_from_seed(seed: int):
    """Deterministic draw matching the hypothesis strategy's support
    (endpoint=True so the ALL_BITS pass-all sentinels are reachable)."""
    rng = np.random.default_rng(seed)
    return (int(rng.integers(4, 301)), int(rng.integers(0, 2**32)),
            int(rng.integers(-2, 6)), int(rng.integers(0, 501)),
            int(rng.integers(1, 0xFFFFFFFF, endpoint=True)),
            int(rng.integers(1, 0xFFFFFFFF, endpoint=True)),
            int(rng.integers(1, 13)))


def _corpus(args):
    n, seed, p_ten, p_ts, p_cat, p_acl, k = args
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((n, 8), dtype=np.float32)
    tenant = rng.integers(-1, 6, n, dtype=np.int32)     # -1 = tombstones
    ts = rng.integers(0, 600, n, dtype=np.int32)
    cat = rng.integers(0, 32, n, dtype=np.int32)
    acl = rng.integers(0, 2**31, n, dtype=np.int64).astype(np.uint32)
    pred = Predicate(tenant=p_ten, min_ts=p_ts, cat_mask=p_cat, acl_bits=p_acl)
    q = rng.standard_normal((2, 8), dtype=np.float32)
    return emb, tenant, ts, cat, acl, pred, q, k


def _oracle_mask(tenant, ts, cat, acl, pred):
    mask = (tenant >= 0) & (ts >= pred.min_ts)
    if pred.tenant != -2:
        mask &= tenant == pred.tenant
    mask &= ((np.uint64(1) << (cat.astype(np.uint64) & np.uint64(31)))
             & np.uint64(pred.cat_mask)) != 0
    mask &= (acl & np.uint32(pred.acl_bits)) != 0
    return mask


def _check_no_leak_and_topk(args):
    emb, tenant, ts, cat, acl, pred, q, k = _corpus(args)
    store = _store_from(emb, tenant, ts, cat, acl)
    scores, slots = unified_query_ref(store, jnp.asarray(q), pred.as_array(), k)
    scores, slots = np.asarray(scores), np.asarray(slots)

    mask = _oracle_mask(tenant, ts, cat, acl, pred)
    ref = q @ emb.T
    ref[:, ~mask] = -np.inf

    for b in range(2):
        # 1. no returned slot violates the predicate
        got = slots[b][slots[b] >= 0]
        assert mask[got].all(), "LEAK: predicate-violating row returned"
        # 2. exactly min(k, qualifying) rows returned
        assert len(got) == min(k, int(mask.sum()))
        # 3. scores are the true top-k, non-increasing
        want = np.sort(ref[b][mask])[::-1][: len(got)]
        have = scores[b][scores[b] > -1e38]
        assert (np.diff(have) <= 1e-6).all()
        np.testing.assert_allclose(have, want, rtol=1e-4, atol=1e-5)


def _check_pallas_same_contract(args):
    emb, tenant, ts, cat, acl, pred, q, k = _corpus(args)
    store = _store_from(emb, tenant, ts, cat, acl)
    s_ref, _ = unified_query_ref(store, jnp.asarray(q), pred.as_array(), k)
    s_pal, i_pal = filtered_topk(jnp.asarray(q), jnp.asarray(emb),
                                 jnp.asarray(tenant), jnp.asarray(ts),
                                 jnp.asarray(cat), jnp.asarray(acl, jnp.uint32),
                                 pred.as_array(), k, blk_n=64)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-5)


def _check_session_front_door(args):
    """The front door adds nothing and removes nothing: a Session's builder
    chain is bit-identical to the reference engine under the principal's
    clauses, and its results can never leave the principal's tenant/ACL."""
    emb, tenant, ts, cat, acl, pred, q, k = _corpus(args)
    n = emb.shape[0]
    db = RagDB(StoreConfig(capacity=n, dim=8, metric="dot"))
    db.ingest(DocBatch(emb=jnp.asarray(emb), tenant=jnp.asarray(tenant),
                       category=jnp.asarray(cat), updated_at=jnp.asarray(ts),
                       acl=jnp.asarray(acl, jnp.uint32),
                       doc_id=jnp.arange(n, dtype=jnp.int32)))
    principal_tenant = abs(pred.tenant) % 6
    principal = Principal(tenant_id=principal_tenant, group_bits=pred.acl_bits)
    res = (db.session(principal).search(q, normalize=False)
           .newer_than(pred.min_ts).limit(k).run())

    lowered = Predicate(tenant=principal_tenant, min_ts=pred.min_ts,
                        acl_bits=pred.acl_bits)
    s_ref, i_ref = unified_query_ref(db.log.snapshot(), jnp.asarray(q),
                                     lowered.as_array(), k)
    assert (np.asarray(i_ref) == res.slots).all()
    assert (np.asarray(s_ref) == res.scores).all()
    for b in range(2):
        got = res.slots[b][res.slots[b] >= 0]
        assert (tenant[got] == principal_tenant).all(), "cross-tenant leak"
        assert ((acl[got] & np.uint32(pred.acl_bits)) != 0).all(), "ACL leak"
        assert (ts[got] >= pred.min_ts).all()


def _check_scheduler_isolation(args):
    """Isolation survives the serving path: plans pushed through the
    admission-controlled scheduler — including ones it degrades under
    pressure or serves stale from cache — can never surface another
    tenant's rows or rows outside the principal's ACL. The scheduler
    never sees a principal; the clauses ride in the lowered plan."""
    from repro.serving.scheduler import (Scheduler, SchedulerConfig,
                                         ServeRequest)

    emb, tenant, ts, cat, acl, pred, q, k = _corpus(args)
    n = emb.shape[0]
    db = RagDB(StoreConfig(capacity=n, dim=8, metric="dot"))
    db.ingest(DocBatch(emb=jnp.asarray(emb), tenant=jnp.asarray(tenant),
                       category=jnp.asarray(cat), updated_at=jnp.asarray(ts),
                       acl=jnp.asarray(acl, jnp.uint32),
                       doc_id=jnp.arange(n, dtype=jnp.int32)))
    # tiny queue + aggressive thresholds: force the degradation/stale
    # machinery on, then serve the same plans twice so the second round
    # can hit the (stale-eligible) result cache
    sched = Scheduler(db, SchedulerConfig(
        slo_ms=0.0, max_queue=4, max_batch=2, degrade_pressure=0.0,
        stale_pressure=0.0, stale_within_s=60.0))
    principals = [Principal(tenant_id=t % 6, group_bits=pred.acl_bits)
                  for t in range(3)]
    plans = [db.session(p).search(q, normalize=False)
             .newer_than(pred.min_ts).limit(k).plan() for p in principals]
    for round_ in range(2):
        results = []
        for i, plan in enumerate(plans):
            if sched.offer(ServeRequest(plan=plan, arrival_t=sched.clock(),
                                        req_id=i)):
                results.extend(sched.run_until_idle())
        for res in results:
            p = principals[res.request.req_id]
            for b in range(q.shape[0]):
                got = res.slots[b][res.slots[b] >= 0]
                assert (tenant[got] == p.tenant_id).all(), \
                    f"cross-tenant leak via scheduler (served={res.served})"
                assert ((acl[got] & np.uint32(pred.acl_bits)) != 0).all(), \
                    f"ACL leak via scheduler (served={res.served})"
                assert (ts[got] >= pred.min_ts).all()


def _check_chaos_isolation(args):
    """Isolation survives the serving path UNDER FAULTS: with a storm firing
    on every query-path site (warm errors, hot-launch failures, finish
    faults, poisoned cache epochs), any row a non-failed response surfaces
    still satisfies the plan's tenant/ACL/ts clauses. Faults may degrade or
    fail a response — they can never widen it. Stall rates are zero so the
    property runs at full speed; the timing-dependent fault classes get
    their own fake-clock tests in tests/test_faults.py."""
    from repro.serving.faults import FaultPlan, FaultRule
    from repro.serving.scheduler import (Scheduler, SchedulerConfig,
                                         ServeRequest)

    emb, tenant, ts, cat, acl, pred, q, k = _corpus(args)
    n = emb.shape[0]
    db = RagDB(StoreConfig(capacity=n, dim=8, metric="dot"))
    db.ingest(DocBatch(emb=jnp.asarray(emb), tenant=jnp.asarray(tenant),
                       category=jnp.asarray(cat), updated_at=jnp.asarray(ts),
                       acl=jnp.asarray(acl, jnp.uint32),
                       doc_id=jnp.arange(n, dtype=jnp.int32)))
    storm_seed = args[1] & 0xFFFF
    db.attach_faults(FaultPlan(storm_seed, {
        "warm.error": FaultRule(rate=0.4),
        "hot.launch": FaultRule(rate=0.3),
        "hot.finish_error": FaultRule(rate=0.2),
        "cache.stale": FaultRule(rate=0.5),
    }))
    sched = Scheduler(db, SchedulerConfig(
        slo_ms=0.0, max_queue=8, max_batch=2, degrade_pressure=0.0,
        stale_pressure=0.0, stale_within_s=60.0, warm_retries=1,
        launch_retries=1, breaker_failures=3, breaker_reset_s=0.0,
        requeue_limit=1, seed=storm_seed))
    principals = [Principal(tenant_id=t % 6, group_bits=pred.acl_bits)
                  for t in range(3)]
    plans = [db.session(p).search(q, normalize=False)
             .newer_than(pred.min_ts).limit(k).plan() for p in principals]
    served = 0
    for round_ in range(2):
        results = []
        for i, plan in enumerate(plans):
            if sched.offer(ServeRequest(plan=plan, arrival_t=sched.clock(),
                                        req_id=i)):
                results.extend(sched.run_until_idle())
        for res in results:
            if res.served == "failed":
                assert (res.slots == -1).all()
                continue
            served += 1
            p = principals[res.request.req_id]
            for b in range(q.shape[0]):
                got = res.slots[b][res.slots[b] >= 0]
                assert (tenant[got] == p.tenant_id).all(), \
                    f"cross-tenant leak under faults (served={res.served})"
                assert ((acl[got] & np.uint32(pred.acl_bits)) != 0).all(), \
                    f"ACL leak under faults (served={res.served})"
                assert (ts[got] >= pred.min_ts).all()
    db.attach_faults(None)


def _check_sharded_affine_isolation(args):
    """SHARDED tenant-affine isolation: through a mesh-built RagDB (tenant
    placement over every local device — S=1 in the tier-1 process, S=8 when
    re-run from the distributed subprocess suite), a tenant-scoped query
    (a) scans ONLY its owning shard (per-shard rows audit), (b) never
    surfaces a POISONED foreign-tenant row crafted to out-score the whole
    corpus, and (c) returns exactly the reference engine's bits."""
    import jax

    from repro.launch.mesh import make_mesh

    emb, tenant, ts, cat, acl, pred, q, k = _corpus(args)
    n = emb.shape[0]
    S = jax.device_count()
    tenant = np.abs(tenant).astype(np.int32) % 6    # live rows (placement key)
    principal_tenant = abs(pred.tenant) % 6
    # two poisoned rows, one per query row: a FOREIGN tenant, maximally
    # eligible on every other clause, embedding aligned with the query so
    # its dot score dwarfs every legitimate row — if any structural gate or
    # mask leaked, it would top both k-lists
    qn = q / np.maximum(np.linalg.norm(q, axis=1, keepdims=True), 1e-6)
    emb = np.concatenate([emb, 100.0 * qn.astype(np.float32)])
    tenant = np.concatenate(
        [tenant, np.full(2, (principal_tenant + 1) % 6, np.int32)])
    ts = np.concatenate([ts, np.full(2, 600, np.int32)])
    cat = np.concatenate([cat, cat[:2]])
    acl = np.concatenate([acl, np.full(2, 0xFFFFFFFF, np.uint32)])
    n += 2
    # tenant placement packs each tenant's rows into its owning shard's
    # contiguous region — size regions for the FULLEST shard, not the mean
    cap = S * (int(np.bincount(tenant % S, minlength=S).max()) + 1)
    mesh = make_mesh((S,), ("data",))
    db = RagDB(StoreConfig(capacity=cap, dim=8, metric="dot"), mesh=mesh,
               shard_axes=("data",), placement="tenant")
    db.ingest(DocBatch(emb=jnp.asarray(emb), tenant=jnp.asarray(tenant),
                       category=jnp.asarray(cat), updated_at=jnp.asarray(ts),
                       acl=jnp.asarray(acl, jnp.uint32),
                       doc_id=jnp.arange(n, dtype=jnp.int32)))
    principal = Principal(tenant_id=principal_tenant, group_bits=pred.acl_bits)
    res = (db.session(principal).search(q, normalize=False)
           .newer_than(pred.min_ts).limit(k).using("sharded").run())

    snap = db.log.snapshot()
    snap_tenant = np.asarray(snap["tenant"])
    for b in range(2):
        got = res.slots[b][res.slots[b] >= 0]
        assert (snap_tenant[got] == principal_tenant).all(), \
            "poisoned foreign-tenant row surfaced through the sharded engine"
        assert (res.scores[b] < 50.0).all(), "poisoned score leaked"
    # (a) the per-shard audit: ONLY the owning shard scanned its region
    owner = principal_tenant % S
    want_rows = [cap // S if s == owner else 0 for s in range(S)]
    assert db.stats.shard_rows_scanned == want_rows, \
        (db.stats.shard_rows_scanned, want_rows)
    # (c) bit-identity with the reference engine on the same snapshot
    lowered = Predicate(tenant=principal_tenant, min_ts=pred.min_ts,
                        acl_bits=pred.acl_bits)
    s_ref, i_ref = unified_query_ref(snap, jnp.asarray(q),
                                     lowered.as_array(), k)
    assert (np.asarray(i_ref) == res.slots).all()
    assert (np.asarray(s_ref) == res.scores).all()


SEED_GRID = list(range(40))

if HAVE_HYPOTHESIS:
    # independent field draws so hypothesis can mutate/shrink each clause
    # (the seed grid below is only the hypothesis-absent fallback)
    corpus_st = st.integers(min_value=4, max_value=300).flatmap(
        lambda n: st.tuples(
            st.just(n),
            st.integers(min_value=0, max_value=2**32 - 1),  # numpy seed
            st.integers(min_value=-2, max_value=5),          # tenant pred
            st.integers(min_value=0, max_value=500),         # min_ts
            st.integers(min_value=1, max_value=0xFFFFFFFF),  # cat mask
            st.integers(min_value=1, max_value=0xFFFFFFFF),  # acl bits
            st.integers(min_value=1, max_value=12),          # k
        ))

    @given(corpus_st)
    @settings(max_examples=40, deadline=None)
    def test_no_leak_and_topk_sound(args):
        _check_no_leak_and_topk(args)

    @given(corpus_st)
    @settings(max_examples=15, deadline=None)
    def test_pallas_kernel_same_contract(args):
        _check_pallas_same_contract(args)

    @given(corpus_st)
    @settings(max_examples=15, deadline=None)
    def test_session_front_door_property(args):
        _check_session_front_door(args)

    @given(corpus_st)
    @settings(max_examples=15, deadline=None)
    def test_scheduler_isolation_property(args):
        _check_scheduler_isolation(args)

    @given(corpus_st)
    @settings(max_examples=15, deadline=None)
    def test_chaos_isolation_property(args):
        _check_chaos_isolation(args)

    @given(corpus_st)
    @settings(max_examples=10, deadline=None)
    def test_sharded_affine_isolation_property(args):
        _check_sharded_affine_isolation(args)
else:
    @pytest.mark.parametrize("seed", SEED_GRID)
    def test_no_leak_and_topk_sound(seed):
        _check_no_leak_and_topk(_args_from_seed(seed))

    @pytest.mark.parametrize("seed", SEED_GRID[:15])
    def test_pallas_kernel_same_contract(seed):
        _check_pallas_same_contract(_args_from_seed(seed))

    @pytest.mark.parametrize("seed", SEED_GRID[:15])
    def test_session_front_door_property(seed):
        _check_session_front_door(_args_from_seed(seed))

    @pytest.mark.parametrize("seed", SEED_GRID[:15])
    def test_scheduler_isolation_property(seed):
        _check_scheduler_isolation(_args_from_seed(seed))

    @pytest.mark.parametrize("seed", SEED_GRID[:15])
    def test_chaos_isolation_property(seed):
        _check_chaos_isolation(_args_from_seed(seed))

    @pytest.mark.parametrize("seed", SEED_GRID[:10])
    def test_sharded_affine_isolation_property(seed):
        _check_sharded_affine_isolation(_args_from_seed(seed))
