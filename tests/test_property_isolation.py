"""Hypothesis property tests on the system's central invariants.

1. LEAKAGE-IMPOSSIBILITY: for ANY corpus, ANY predicate, ANY query, no row
   returned by the unified engine violates the predicate (the paper's
   row-level-security claim, attacked adversarially).
2. TOP-K SOUNDNESS: returned scores are the true top-k of the masked score
   vector, in non-increasing order.
3. The filtered_topk Pallas kernel satisfies the same contract as the ref.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.query import Predicate, unified_query_ref
from repro.kernels.filtered_topk.ops import filtered_topk


def _store_from(emb, tenant, ts, cat, acl):
    n = emb.shape[0]
    return {
        "emb": jnp.asarray(emb), "tenant": jnp.asarray(tenant),
        "category": jnp.asarray(cat), "updated_at": jnp.asarray(ts),
        "acl": jnp.asarray(acl, jnp.uint32),
        "doc_id": jnp.arange(n, dtype=jnp.int32),
        "version": jnp.zeros(n, jnp.int32),
        "commit_ts": jnp.int32(1), "n_live": jnp.int32(n),
    }


corpus_st = st.integers(min_value=4, max_value=300).flatmap(
    lambda n: st.tuples(
        st.just(n),
        st.integers(min_value=0, max_value=2**32 - 1),  # numpy seed
        st.integers(min_value=-2, max_value=5),          # tenant pred
        st.integers(min_value=0, max_value=500),         # min_ts
        st.integers(min_value=1, max_value=0xFFFFFFFF),  # cat mask
        st.integers(min_value=1, max_value=0xFFFFFFFF),  # acl bits
        st.integers(min_value=1, max_value=12),          # k
    ))


@given(corpus_st)
@settings(max_examples=40, deadline=None)
def test_no_leak_and_topk_sound(args):
    n, seed, p_ten, p_ts, p_cat, p_acl, k = args
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((n, 8), dtype=np.float32)
    tenant = rng.integers(-1, 6, n, dtype=np.int32)     # -1 = tombstones
    ts = rng.integers(0, 600, n, dtype=np.int32)
    cat = rng.integers(0, 32, n, dtype=np.int32)
    acl = rng.integers(0, 2**31, n, dtype=np.int64).astype(np.uint32)
    store = _store_from(emb, tenant, ts, cat, acl)
    pred = Predicate(tenant=p_ten, min_ts=p_ts, cat_mask=p_cat, acl_bits=p_acl)
    q = rng.standard_normal((2, 8), dtype=np.float32)

    scores, slots = unified_query_ref(store, jnp.asarray(q), pred.as_array(), k)
    scores, slots = np.asarray(scores), np.asarray(slots)

    mask = (tenant >= 0) & (ts >= p_ts)
    if p_ten != -2:
        mask &= tenant == p_ten
    mask &= ((np.uint64(1) << (cat.astype(np.uint64) & np.uint64(31)))
             & np.uint64(p_cat)) != 0
    mask &= (acl & np.uint32(p_acl)) != 0
    ref = q @ emb.T
    ref[:, ~mask] = -np.inf

    for b in range(2):
        # 1. no returned slot violates the predicate
        got = slots[b][slots[b] >= 0]
        assert mask[got].all(), "LEAK: predicate-violating row returned"
        # 2. exactly min(k, qualifying) rows returned
        assert len(got) == min(k, int(mask.sum()))
        # 3. scores are the true top-k, non-increasing
        want = np.sort(ref[b][mask])[::-1][: len(got)]
        have = scores[b][scores[b] > -1e38]
        assert (np.diff(have) <= 1e-6).all()
        np.testing.assert_allclose(have, want, rtol=1e-4, atol=1e-5)


@given(corpus_st)
@settings(max_examples=15, deadline=None)
def test_pallas_kernel_same_contract(args):
    n, seed, p_ten, p_ts, p_cat, p_acl, k = args
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((n, 8), dtype=np.float32)
    tenant = rng.integers(-1, 6, n, dtype=np.int32)
    ts = rng.integers(0, 600, n, dtype=np.int32)
    cat = rng.integers(0, 32, n, dtype=np.int32)
    acl = rng.integers(0, 2**31, n, dtype=np.int64).astype(np.uint32)
    pred = Predicate(tenant=p_ten, min_ts=p_ts, cat_mask=p_cat, acl_bits=p_acl)
    q = rng.standard_normal((2, 8), dtype=np.float32)

    store = _store_from(emb, tenant, ts, cat, acl)
    s_ref, _ = unified_query_ref(store, jnp.asarray(q), pred.as_array(), k)
    s_pal, i_pal = filtered_topk(jnp.asarray(q), jnp.asarray(emb),
                                 jnp.asarray(tenant), jnp.asarray(ts),
                                 jnp.asarray(cat), jnp.asarray(acl, jnp.uint32),
                                 pred.as_array(), k, blk_n=64)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-5)
