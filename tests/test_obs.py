"""Observability layer: span trees, flight recorder, calibration audit.

The contracts under test (ISSUE 10):

  * COMPLETENESS — with the tracer on, every executed request yields a
    finished trace whose span tree is well-formed (root ``request``, valid
    parent links, closed monotone intervals nested inside the root) and
    covers the pipeline stages the request actually crossed
    (cache_lookup -> launch -> device_sync -> merge, queue/plan under the
    scheduler).
  * ZERO-COST DISABLED — tracer off is the default and results are
    bit-identical to tracer on: tracing observes, never steers.
  * PINNING — the flight recorder's ring is bounded, pinned (slo /
    degraded / fault / failed) traces survive the ring rolling past them,
    the pin list is bounded too (drops counted), and fault/degradation
    pins are applied automatically on the serving path.
  * EXPORT — the Perfetto ``trace_event`` conversion is JSON-round-trip
    stable and `tools/trace_report.py` rebuilds the identical event list
    from a dump file.
  * CALIBRATION — predicted-vs-measured recording is always on (tracer
    independent), keyed by (engine, N-bucket, G, k), and
    `CostModel.calibrated` rescales curves by the measured drift.
"""
import json

import numpy as np
import pytest

from repro.api import RagDB
from repro.api.planner import CostModel, PlannerConfig
from repro.core import StoreConfig
from repro.data.corpus import DAY_S, CorpusConfig, make_corpus
from repro.obs import CalibrationTable, FlightRecorder, Tracer
from repro.obs.calibration import pow2_bucket
from repro.serving.faults import FaultPlan, FaultRule
from repro.serving.metrics import MetricsRegistry
from repro.serving.scheduler import Scheduler, SchedulerConfig, ServeRequest
from tests.test_scheduler import FakeClock

ALL_BITS = 0xFFFFFFFF


# -- helpers ---------------------------------------------------------------

def _db(n_docs=300, dim=16, tiered=False, measured=False):
    ccfg = CorpusConfig(n_docs=n_docs, dim=dim, n_tenants=3, n_categories=4)
    scfg = StoreConfig(capacity=512, dim=dim)
    kw = {}
    if tiered:
        kw = dict(warm_cfg=scfg, hot_window_s=90 * DAY_S)
    if measured:
        kw["planner_cfg"] = PlannerConfig.with_measured_costs()
    db = RagDB(scfg, now_ts=ccfg.now_ts, **kw)
    db.ingest(make_corpus(ccfg))
    if tiered:
        assert db.router.warm.n_docs > 0
    return db, ccfg


def _plans(db, ccfg, n, seed=0, k=6):
    rng = np.random.default_rng(seed)
    sess = db.admin_session()
    return [sess.search(rng.standard_normal(ccfg.dim).astype(np.float32),
                        normalize=False).limit(k).plan() for _ in range(n)]


def _assert_well_formed(trace):
    """Structural span-tree invariants: closed, monotone, parent-linked,
    nested inside the root interval."""
    assert trace.finished
    spans = trace.spans
    root = spans[0]
    assert root.name == "request" and root.parent_id == -1
    ids = {s.span_id for s in spans}
    assert len(ids) == len(spans)           # unique ids
    for s in spans:
        assert s.t1 is not None, f"span {s.name} left open"
        assert s.t1 >= s.t0
        if s is not root:
            assert s.parent_id in ids       # valid parent link
            # batch-shared fans are stamped with one shared clock pair, so
            # every child interval nests inside the root's
            assert root.t0 <= s.t0 and s.t1 <= root.t1 + 1e-9


# -- span-tree completeness ------------------------------------------------

def test_execute_trace_covers_pipeline_stages():
    db, ccfg = _db()
    rec = FlightRecorder()
    db.attach_tracer(Tracer(enabled=True, recorder=rec))
    plans = _plans(db, ccfg, 4)
    db.execute(plans)                       # cache on: misses, full pipeline
    got = rec.traces()
    assert len(got) == len(plans)
    for t in got:
        _assert_well_formed(t)
        names = [s.name for s in t.spans]
        for stage in ("request", "cache_lookup", "launch", "device_sync",
                      "merge"):
            assert stage in names, (stage, names)
        assert t.root.ann["served"] in ("fresh", "cache", "stale")
    # no cache consulted -> no cache_lookup span (observe, never pad)
    db.execute(_plans(db, ccfg, 2, seed=9), use_cache=False)
    nocache = rec.traces()[-2:]
    assert all("cache_lookup" not in [s.name for s in t.spans]
               for t in nocache)


def test_cache_hit_trace_short_circuits():
    db, ccfg = _db()
    rec = FlightRecorder()
    db.attach_tracer(Tracer(enabled=True, recorder=rec))
    plans = _plans(db, ccfg, 2)
    db.execute(plans)                       # cold: full pipeline
    db.execute(plans)                       # warm: cache hits
    hits = [t for t in rec.traces()
            if any(s.name == "cache_lookup" and s.ann.get("outcome") == "hit"
                   for s in t.spans)]
    assert len(hits) == len(plans)
    for t in hits:
        _assert_well_formed(t)
        names = [s.name for s in t.spans]
        assert "launch" not in names        # hit never reaches the device
        assert t.root.ann["served"] == "cache"


def test_scheduler_trace_adds_queue_and_plan_spans():
    db, ccfg = _db()
    rec = FlightRecorder()
    db.attach_tracer(Tracer(enabled=True, recorder=rec))
    clock = FakeClock()
    sched = Scheduler(db, SchedulerConfig(slo_ms=1e9, max_queue=16,
                                          max_batch=4, degrade_pressure=2.0,
                                          stale_pressure=2.0),
                      clock=clock, metrics=MetricsRegistry(),
                      sleep=clock.advance)
    for i, plan in enumerate(_plans(db, ccfg, 3)):
        assert sched.offer(ServeRequest(plan=plan, arrival_t=clock(),
                                        req_id=i, tenant=i % 3))
    results = sched.run_until_idle()
    assert len(results) == 3
    assert len(rec.traces()) == 3
    for t in rec.traces():
        _assert_well_formed(t)
        names = [s.name for s in t.spans]
        assert names[:2] == ["request", "queue"]
        assert "plan" in names and "launch" in names
        assert t.root.ann["deadline_met"] is True
        assert "e2e_ms" in t.root.ann and "req_id" in t.root.ann


# -- disabled path: bit-identity and true zero-cost ------------------------

def test_tracer_disabled_results_bit_identical():
    db, ccfg = _db()
    plans = _plans(db, ccfg, 4)
    assert not db.tracer.enabled            # off is the default
    off = db.execute(plans, use_cache=False)
    db.attach_tracer(Tracer(enabled=True, recorder=FlightRecorder()))
    on = db.execute(plans, use_cache=False)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a, b)
    assert db.tracer.traces_started == len(plans)
    db.attach_tracer(Tracer(enabled=False))
    db.execute(plans, use_cache=False)
    assert db.tracer.traces_started == 0    # disabled path makes no traces


# -- flight-recorder pinning rules -----------------------------------------

def test_recorder_ring_bounded_and_pins_survive():
    rec = FlightRecorder(cap=4, pin_cap=2)
    tr = Tracer(enabled=True, recorder=rec)
    for i in range(20):
        t = tr.trace("request", req_id=i)
        if i in (1, 5, 9):                  # 3 pinned > pin_cap=2
            t.pin("failed")
        t.finish()
    assert rec.recorded == 20
    assert len(rec.ring) == 4               # ring bound holds
    assert [t.root.ann["req_id"] for t in rec.ring] == [16, 17, 18, 19]
    # first pin_cap pinned traces retained even after the ring rolled
    assert [t.root.ann["req_id"] for t in rec.pinned] == [1, 5]
    assert rec.pin_drops == 1               # the refused third pin counted
    # pinned-first, deduplicated view + root-annotation lookup
    assert [t.root.ann["req_id"] for t in rec.traces()][:2] == [1, 5]
    assert [t.root.ann["req_id"] for t in rec.find(req_id=5)] == [5]


def test_degraded_and_fault_pins_applied_on_serving_path():
    db, ccfg = _db(tiered=True)
    rec = FlightRecorder()
    db.attach_tracer(Tracer(enabled=True, recorder=rec))
    db.attach_faults(FaultPlan(0, {"warm.error": FaultRule(rate=1.0)}))
    clock = FakeClock()
    sched = Scheduler(db, SchedulerConfig(slo_ms=1e9, max_queue=16,
                                          max_batch=4, degrade_pressure=2.0,
                                          stale_pressure=2.0, warm_retries=0),
                      clock=clock, metrics=MetricsRegistry(),
                      sleep=clock.advance)
    rng = np.random.default_rng(0)
    plan = db.admin_session().search(
        rng.standard_normal(ccfg.dim).astype(np.float32),
        normalize=False).limit(6).plan()
    assert plan.route == "hot+warm"
    sched.offer(ServeRequest(plan=plan, arrival_t=clock(), req_id=0))
    (res,) = sched.run_until_idle()
    assert res.degraded                     # warm tier failed over
    (t,) = rec.find(req_id=0)
    assert "degraded" in t.pins and "fault" in t.pins
    assert t.root.ann["degraded"]           # names the rung
    faults = [site for s in t.spans for site in s.ann.get("faults", ())]
    assert "warm.error" in faults           # the injected site, by name


def test_failed_request_trace_pins_failed_with_fault_annotation():
    db, ccfg = _db()
    rec = FlightRecorder()
    db.attach_tracer(Tracer(enabled=True, recorder=rec))
    db.attach_faults(FaultPlan(0, {"hot.launch": FaultRule(rate=1.0)}))
    clock = FakeClock()
    sched = Scheduler(db, SchedulerConfig(slo_ms=1e9, max_queue=16,
                                          max_batch=4, degrade_pressure=2.0,
                                          stale_pressure=2.0,
                                          launch_retries=0, requeue_limit=0),
                      clock=clock, metrics=MetricsRegistry(),
                      sleep=clock.advance)
    (plan,) = _plans(db, ccfg, 1)
    sched.offer(ServeRequest(plan=plan, arrival_t=clock(), req_id=7))
    (res,) = sched.run_until_idle()
    assert res.served == "failed"
    (t,) = rec.find(req_id=7)
    assert "failed" in t.pins and "fault" in t.pins
    assert t.root.ann["served"] == "failed"
    faults = [site for s in t.spans for site in s.ann.get("faults", ())]
    assert "hot.launch" in faults


# -- Perfetto export round-trip --------------------------------------------

def test_perfetto_export_round_trips_and_matches_offline_tool():
    db, ccfg = _db()
    rec = FlightRecorder()
    db.attach_tracer(Tracer(enabled=True, recorder=rec))
    db.execute(_plans(db, ccfg, 3), use_cache=False)

    d = json.loads(json.dumps(rec.to_perfetto()))   # JSON round-trip
    events = d["traceEvents"]
    metas = [e for e in events if e["ph"] == "M"]
    xs = [e for e in events if e["ph"] == "X"]
    assert len(metas) == len(rec.traces())
    n_closed = sum(1 for t in rec.traces() for s in t.spans
                   if s.t1 is not None)
    assert len(xs) == n_closed
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0       # normalized to t_base
        assert {"span_id", "parent_id"} <= set(e["args"])
        assert e["cat"] == "serve"
    # every X event's tid maps to a declared pseudo-thread
    assert {e["tid"] for e in xs} <= {e["tid"] for e in metas}

    # the offline tool rebuilds the identical event list from a dump
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "trace_report", os.path.join(os.path.dirname(__file__), "..",
                                     "tools", "trace_report.py"))
    trace_report = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(trace_report)
    dump = json.loads(json.dumps(rec.to_dict()))
    assert dump["schema"] == "repro.obs.flight_recorder/v1"
    assert trace_report.to_perfetto(dump) == d


# -- calibration audit -----------------------------------------------------

def test_calibration_always_on_and_keyed_by_shape():
    db, ccfg = _db(measured=True)
    assert not db.tracer.enabled
    k = 6
    plans = _plans(db, ccfg, 4, k=k)
    db.execute(plans, use_cache=False)
    cal = db.calibration
    assert cal.recorded > 0                 # tracer off, audit still on
    (key,) = cal.units
    engine, nb, groups, kk = key
    assert engine == plans[0].engine
    assert nb == pow2_bucket(plans[0].n_rows) and kk == k
    u = cal.units[key]
    assert u["rows"] == len(plans)
    assert u["priced"] == u["count"] and u["predicted_ms"] > 0
    assert u["device_ms"] >= u["launch_ms"] > 0
    snap = cal.snapshot()
    assert snap["engines"][engine]["ratio"] is not None
    assert "calibration:" in db.explain()


def test_cost_model_calibrated_rescales_by_drift():
    cm = CostModel(curves=(("ref", ((1000, 1.0), (4000, 4.0))),
                           ("ivf", ((1000, 0.5), (4000, 2.0)))))
    base = cm.estimate_ms("ref", 1000)
    t = CalibrationTable()
    t.record_unit(engine="ref", n_rows=1000, groups=8, k=8, rows=8,
                  predicted_ms=2.0, launch_ms=1.0, sync_ms=3.0,
                  rows_scanned=1000)        # measured 2x the prediction
    cal = cm.calibrated(t)
    assert cal.estimate_ms("ref", 1000) == pytest.approx(2 * base)
    # identity cases: no table, empty table, engine without drift data
    assert cm.calibrated(None) is cm
    assert cm.calibrated(CalibrationTable()) is cm
    assert cm.calibrated(t).estimate_ms("ivf", 1000) == \
        pytest.approx(cm.estimate_ms("ivf", 1000))
