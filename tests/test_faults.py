"""Chaos property suite: the stack under deterministic, seeded faults.

The contract (ISSUE 8 / the paper's reliability claims, proven adversarially):
under ANY fault schedule, every served response is

  * CORRECT      — bit-identical to the fault-free execution of the plan
                   that ran, or
  * DEGRADED     — explicitly annotated (``warm-unavailable`` / ladder rungs
                   / ``served == "stale"`` within its declared bound), or
  * SHED/FAILED  — ``served == "failed"`` with sentinel scores/slots,

never silently wrong, never cross-tenant, never mixed-state. One test per
fault class asserts the classification (warm stall, warm error, hot-launch
failure, mid-commit crash, stale cache epoch); the crash grid proves the
TransactionLog's write-ahead intent journal recovers bit-identically to the
pre- or post-write snapshot at EVERY injected crash point (inconsistency
count == 0); the storm test sweeps a seed grid over every query-path site
at once.

All timing runs on the injected fake clock (faults stall via
``clock.advance``), so stalls, timeouts, backoff, and breaker resets are
deterministic and instant.
"""
import numpy as np
import pytest

from repro.api import RagDB
from repro.core import Principal, StoreConfig
from repro.core.store import DocBatch
from repro.core.transactions import CRASH_POINTS
from repro.data.corpus import DAY_S, CorpusConfig, make_corpus
from repro.index.lexical import LexicalConfig
from repro.serving.faults import (CircuitBreaker, CrashError, FaultPlan,
                                  FaultRule, WarmTierError)
from repro.serving.metrics import MetricsRegistry
from repro.serving.scheduler import Scheduler, SchedulerConfig, ServeRequest
from tests.test_scheduler import FakeClock

ALL_BITS = 0xFFFFFFFF


# -- helpers ---------------------------------------------------------------

def _tiered_db() -> tuple[RagDB, CorpusConfig]:
    """Two-tier RagDB: recent docs hot, old docs warm — warm probes (and
    their faults) are reachable through unconstrained queries."""
    ccfg = CorpusConfig(n_docs=400, dim=16, n_tenants=3, n_categories=4)
    scfg = StoreConfig(capacity=1024, dim=16)
    db = RagDB(scfg, warm_cfg=scfg, hot_window_s=90 * DAY_S,
               now_ts=ccfg.now_ts)
    db.ingest(make_corpus(ccfg))
    assert db.router.warm.n_docs > 0
    return db, ccfg


def _sched(db, clock, **over) -> Scheduler:
    """Hardened scheduler on a fake clock; pressure degradation disabled so
    the only degradations in these tests are fault-driven."""
    base = dict(slo_ms=1e9, max_queue=64, max_batch=8,
                degrade_pressure=2.0, stale_pressure=2.0)
    base.update(over)
    return Scheduler(db, SchedulerConfig(**base), clock=clock,
                     metrics=MetricsRegistry(), sleep=clock.advance)


def _admin_req(db, clock, q, k=6, req_id=0):
    plan = db.admin_session().search(q, normalize=False).limit(k).plan()
    assert plan.route == "hot+warm"
    return ServeRequest(plan=plan, arrival_t=clock(), req_id=req_id)


def _clean_ref(db, plan):
    """Fault-free execution of exactly the plan that ran (faults + guard
    detached, cache bypassed) — the bit-identity reference."""
    saved, guard = db.faults, db.warm_guard
    db.attach_faults(None)
    db.warm_guard = None
    try:
        return db.execute([plan], use_cache=False)
    finally:
        db.attach_faults(saved)
        db.warm_guard = guard


def _serve_one(db, clock, req, **cfg):
    sched = _sched(db, clock, **cfg)
    assert sched.offer(req)
    res = sched.run_until_idle()
    assert len(res) == 1
    return res[0], sched


# -- FaultPlan determinism -------------------------------------------------

def test_fault_plan_schedule_is_pure_in_seed_site_and_call_index():
    mk = lambda seed: FaultPlan(seed, {
        "a": FaultRule(rate=0.4), "b": FaultRule(rate=0.4, after=3, until=9)})
    runs = [[(p.fires("a"), p.fires("b")) for _ in range(32)]
            for p in (mk(7), mk(7))]
    assert runs[0] == runs[1], "same seed must replay the same schedule"
    other = [( FaultPlan(8, {"a": FaultRule(rate=0.4)}).fires("a"))
             for _ in range(0)]  # distinct-seed check below, over one plan
    p7, p8 = mk(7), mk(8)
    assert ([p7.fires("a") for _ in range(64)]
            != [p8.fires("a") for _ in range(64)])
    # windows gate firing without reshuffling the stream
    assert all(not f for f, _ in runs[0][:0])
    b_fired = [b for _, b in runs[0]]
    assert not any(b_fired[:3]) and not any(b_fired[9:])


def test_fault_plan_at_schedule_and_counters():
    p = FaultPlan(0, {"x": FaultRule(at=(0, 2))})
    assert [p.fires("x") for _ in range(4)] == [True, False, True, False]
    assert p.counters()["x"] == (4, 2)
    assert p.total_fired() == 2
    p.clear()
    assert not p.fires("x")


def test_circuit_breaker_state_machine():
    clock = FakeClock()
    trans = []
    br = CircuitBreaker(2, 1.0, clock=clock, on_transition=trans.append)
    assert br.allow()
    br.record_failure()
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "open" and not br.allow()
    clock.advance(1.5)
    assert br.allow() and br.state == "half-open"   # one probe through
    br.record_failure()
    assert br.state == "open"                        # failed probe re-opens
    clock.advance(1.5)
    assert br.allow()
    br.record_success()
    assert br.state == "closed" and br.allow()
    assert trans == ["open", "half-open", "open", "half-open", "closed"]


# -- fault class 1: warm error (transient -> retried -> CORRECT) -----------

def test_warm_error_is_retried_to_a_bit_identical_response():
    db, ccfg = _tiered_db()
    clock = FakeClock()
    db.attach_faults(FaultPlan(0, {"warm.error": FaultRule(at=(0,))},
                               sleep=clock.advance))
    q = np.random.default_rng(3).standard_normal(ccfg.dim).astype(np.float32)
    res, sched = _serve_one(db, clock, _admin_req(db, clock, q),
                            warm_retries=2)
    assert res.served == "fresh" and res.degraded == ()
    s, sl, tr = _clean_ref(db, res.request.plan)
    assert (np.array_equal(res.scores, s) and np.array_equal(res.slots, sl)
            and np.array_equal(res.tiers, tr)), \
        "retried response must be bit-identical to fault-free"
    assert sched.metrics.counter_total("warm_errors") == 1
    assert sched.metrics.counter_total("warm_retries") == 1
    db.attach_faults(None)


# -- fault class 2: warm stall (timeout -> hot-only, EXPLICITLY DEGRADED) --

def test_warm_stall_times_out_to_explicit_hot_only_degradation():
    db, ccfg = _tiered_db()
    clock = FakeClock()
    db.attach_faults(FaultPlan(
        0, {"warm.stall": FaultRule(rate=1.0, stall_s=0.05)},
        sleep=clock.advance))
    q = np.random.default_rng(4).standard_normal(ccfg.dim).astype(np.float32)
    res, sched = _serve_one(db, clock, _admin_req(db, clock, q),
                            warm_timeout_ms=10.0, warm_retries=1,
                            breaker_failures=10)
    assert any("warm-unavailable" in d for d in res.degraded), \
        "a timed-out warm probe must surface as explicit degradation"
    assert sched.metrics.counter_total("warm_timeouts") == 2   # 1 + 1 retry
    assert sched.metrics.counter_total("warm_failovers") == 1
    assert db.stats.warm_failovers == 1
    # the hot-only rows really are hot-tier rows
    assert (res.tiers[res.slots >= 0] == 0).all()
    # the degraded chunk must NOT have been cached: the same query served
    # fault-free computes fresh and is bit-identical to the clean reference
    db.attach_faults(None)
    req2 = _admin_req(db, clock, q, req_id=1)
    res2, _ = _serve_one(db, clock, req2)
    assert res2.served == "fresh" and res2.degraded == ()
    s, sl, tr = _clean_ref(db, res2.request.plan)
    assert np.array_equal(res2.scores, s) and np.array_equal(res2.slots, sl)


# -- fault class 3: hot-launch failure (retried; exhausted -> FAILED) ------

def test_hot_launch_fault_is_retried_then_bit_identical():
    db, ccfg = _tiered_db()
    clock = FakeClock()
    db.attach_faults(FaultPlan(0, {"hot.launch": FaultRule(at=(0,))},
                               sleep=clock.advance))
    q = np.random.default_rng(5).standard_normal(ccfg.dim).astype(np.float32)
    res, sched = _serve_one(db, clock, _admin_req(db, clock, q),
                            launch_retries=2, use_cache=False)
    assert res.served == "fresh" and res.degraded == ()
    assert sched.metrics.counter_total("launch_retries") == 1
    s, sl, tr = _clean_ref(db, res.request.plan)
    assert np.array_equal(res.scores, s) and np.array_equal(res.slots, sl)
    db.attach_faults(None)


def test_hot_launch_exhaustion_fails_explicitly_never_wedges():
    db, ccfg = _tiered_db()
    clock = FakeClock()
    db.attach_faults(FaultPlan(0, {"hot.launch": FaultRule(rate=1.0)},
                               sleep=clock.advance))
    q = np.random.default_rng(6).standard_normal(ccfg.dim).astype(np.float32)
    res, sched = _serve_one(db, clock, _admin_req(db, clock, q),
                            launch_retries=2, use_cache=False)
    assert res.served == "failed"
    assert (res.slots == -1).all(), "failed responses carry sentinel slots"
    assert sched.metrics.counter_total("launch_failures") == 1
    assert sched.metrics.counter_total("failed") == 1
    db.attach_faults(None)


# -- fault class 4: stale cache epoch (poisoned read REJECTED) -------------

def test_stale_epoch_cache_read_is_rejected_and_recomputed():
    db, ccfg = _tiered_db()
    clock = FakeClock()
    q = np.random.default_rng(7).standard_normal(ccfg.dim).astype(np.float32)
    # 1) fill the cache under the current epoch
    res0, _ = _serve_one(db, clock, _admin_req(db, clock, q))
    assert res0.served == "fresh"
    # 2) a write bumps the commit epoch, invalidating the entry's key
    hot_doc = next(iter(db.log._slot_of_doc))
    db.update([hot_doc], np.ones((1, ccfg.dim), np.float32), [ccfg.now_ts])
    # 3) a poisoned cache layer serves the newest entry ignoring epochs —
    #    the epoch guard must refuse it and fall through to fresh compute
    db.attach_faults(FaultPlan(0, {"cache.stale": FaultRule(rate=1.0)},
                               sleep=clock.advance))
    res1, _ = _serve_one(db, clock, _admin_req(db, clock, q, req_id=1))
    assert db.stats.stale_epoch_rejected >= 1
    assert res1.served == "fresh" and res1.degraded == ()
    s, sl, tr = _clean_ref(db, res1.request.plan)
    assert np.array_equal(res1.scores, s) and np.array_equal(res1.slots, sl), \
        "a rejected poisoned read must yield the post-write answer"
    db.attach_faults(None)


# -- breaker: trips to hot-only, recovers after faults stop ----------------

def test_breaker_trips_to_hot_only_and_recovers_after_faults_stop():
    db, ccfg = _tiered_db()
    clock = FakeClock()
    plan_f = FaultPlan(0, {"warm.error": FaultRule(rate=1.0)},
                       sleep=clock.advance)
    db.attach_faults(plan_f)
    sched = _sched(db, clock, warm_retries=0, breaker_failures=2,
                   breaker_reset_s=1.0, use_cache=False)
    rng = np.random.default_rng(8)
    results = []
    for i in range(4):
        q = rng.standard_normal(ccfg.dim).astype(np.float32)
        assert sched.offer(_admin_req(db, clock, q, req_id=i))
        results.extend(sched.run_until_idle())
    assert len(results) == 4
    assert all(any("warm-unavailable" in d for d in r.degraded)
               for r in results), "breaker-open serving must stay explicit"
    assert sched.guard.state == "open"
    assert sched.metrics.counter_total("breaker_skips") >= 1
    # while open, the warm tier is not probed at all
    calls_while_open = plan_f.calls.get("warm.error", 0)
    q = rng.standard_normal(ccfg.dim).astype(np.float32)
    assert sched.offer(_admin_req(db, clock, q, req_id=10))
    results.extend(sched.run_until_idle())
    assert plan_f.calls.get("warm.error", 0) == calls_while_open
    # faults stop; after reset_s the half-open probe succeeds -> closed,
    # and the very next response is clean (recovery within one step)
    plan_f.clear()
    clock.advance(2.0)
    q = rng.standard_normal(ccfg.dim).astype(np.float32)
    assert sched.offer(_admin_req(db, clock, q, req_id=11))
    (rec,) = sched.run_until_idle()
    assert rec.degraded == () and rec.served == "fresh"
    assert sched.guard.state == "closed"
    s, sl, tr = _clean_ref(db, rec.request.plan)
    assert np.array_equal(rec.scores, s) and np.array_equal(rec.slots, sl)
    assert sched.metrics.counter_total("breaker_open") >= 1
    assert sched.metrics.counter_total("breaker_closed") >= 1
    db.attach_faults(None)


# -- fault class 5: mid-commit crash grid (WAL recovery bit-identity) ------

def _crash_db() -> RagDB:
    """Hot-tier RagDB with ivf + lexical write-through and a populated
    free-slot list — every publish step of every op does real work."""
    ccfg = CorpusConfig(n_docs=48, dim=8, n_tenants=2, n_categories=2,
                        vocab_size=64, doc_terms=4, n_entity_terms=8)
    db = RagDB(StoreConfig(capacity=96, dim=8),
               lexical_cfg=LexicalConfig(vocab_size=64, doc_terms=4))
    db.ingest(make_corpus(ccfg))
    db.build_index()
    db.delete([40, 41, 42])          # free slots -> ingest recycles
    return db


def _mk_batch(ids, dim=8, seed=11):
    rng = np.random.default_rng(seed)
    n = len(ids)
    return DocBatch(
        emb=rng.standard_normal((n, dim)).astype(np.float32),
        tenant=np.zeros(n, np.int32), category=np.zeros(n, np.int32),
        updated_at=np.full(n, 5, np.int32),
        acl=np.full(n, ALL_BITS, np.uint32),
        doc_id=np.asarray(ids, np.int32),
        terms=rng.integers(0, 64, (n, 4)).astype(np.int32),
        tfs=rng.integers(1, 4, (n, 4)).astype(np.int32))


def _apply_op(db, op):
    if op == "ingest":
        db.log.ingest(_mk_batch([100, 101, 102, 103]))
    elif op == "update":
        db.log.update([1, 2], np.full((2, 8), 0.5, np.float32), [7, 7])
    else:
        db.log.delete([3, 4])


def _fingerprint(db) -> dict:
    log = db.log
    fp = {f"store.{k}": np.asarray(v).copy()
          for k, v in log.snapshot().items()}
    fp["cursor"] = log._cursor
    fp["slot_of_doc"] = dict(log._slot_of_doc)
    fp["free_slots"] = tuple(log._free_slots)
    fp["commit_count"] = log.commit_count
    lx = db.lex.snapshot()
    fp.update({f"lex.{k}": np.asarray(v).copy() for k, v in lx.items()})
    fp["lex.commits"] = db.lex.commit_count
    ix = db.index
    fp["ivf.members"] = np.asarray(ix.members).copy()
    fp["ivf.overflow"] = tuple(ix.overflow)
    fp["ivf.slot_pos"] = dict(ix._slot_pos)
    fp["ivf.epoch"] = ix.epoch
    return fp


def _fp_diff(a: dict, b: dict) -> list[str]:
    out = []
    for k in a:
        va, vb = a[k], b[k]
        same = (np.array_equal(va, vb) if isinstance(va, np.ndarray)
                else va == vb)
        if not same:
            out.append(k)
    return out


@pytest.mark.parametrize("point", CRASH_POINTS)
@pytest.mark.parametrize("op", ["ingest", "update", "delete"])
def test_crash_recovery_grid_bit_identical_never_torn(op, point):
    # reference pre/post states from a fault-free twin
    ref = _crash_db()
    fp_pre = _fingerprint(ref)
    _apply_op(ref, op)
    fp_post = _fingerprint(ref)
    # victim: identical construction, crash injected at exactly this point
    db = _crash_db()
    assert not _fp_diff(_fingerprint(db), fp_pre), "twin construction drifted"
    db.log.faults = FaultPlan(0, {f"txn.{op}.{point}": FaultRule(at=(0,))})
    with pytest.raises(CrashError):
        _apply_op(db, op)
    outcome = db.log.recover()
    fp_rec = _fingerprint(db)
    # commit_count monotonicity: never decreases, advances at most once
    assert fp_rec["commit_count"] in (fp_pre["commit_count"],
                                      fp_post["commit_count"])
    # THE invariant: recovered state is bit-identical to pre- OR post-write
    # — torn mixes (inconsistency) are structurally impossible
    diff_pre, diff_post = _fp_diff(fp_rec, fp_pre), _fp_diff(fp_rec, fp_post)
    assert not diff_pre or not diff_post, (
        f"TORN STATE after crash at {op}.{point}: "
        f"differs from pre in {diff_pre} and from post in {diff_post}")
    if point in ("prepare", "intent"):
        assert outcome in ("noop", "rolled-back") and not diff_pre
    else:
        assert outcome == "rolled-forward" and not diff_post


def test_crash_then_recover_then_write_again_is_clean():
    """Recovery leaves the log fully writable: the next write commits
    normally and recover() is a no-op."""
    db = _crash_db()
    db.log.faults = FaultPlan(0, {"txn.ingest.ivf": FaultRule(at=(0,))})
    with pytest.raises(CrashError):
        _apply_op(db, "ingest")
    assert db.log.recover() == "rolled-forward"
    db.log.faults = None
    before = db.log.commit_count
    db.log.ingest(_mk_batch([200, 201], seed=12))
    assert db.log.commit_count == before + 1
    assert db.log.recover() == "noop"
    assert db.log.has_doc(200) and db.log.has_doc(103)


# -- the storm: every query-path site at once, across a seed grid ----------

STORM_SEEDS = list(range(6))


@pytest.fixture(scope="module")
def storm_db():
    return _tiered_db()


@pytest.mark.parametrize("seed", STORM_SEEDS)
def test_chaos_storm_every_response_classified(storm_db, seed):
    db, ccfg = storm_db
    clock = FakeClock()
    plan_f = FaultPlan(seed, {
        "warm.error": FaultRule(rate=0.3),
        "warm.stall": FaultRule(rate=0.2, stall_s=0.05),
        "hot.launch": FaultRule(rate=0.15),
        "hot.wedge": FaultRule(rate=0.1, stall_s=0.5),
        "hot.finish_error": FaultRule(rate=0.1),
        "cache.stale": FaultRule(rate=0.5),
    }, sleep=clock.advance)
    db.attach_faults(plan_f)
    try:
        sched = _sched(db, clock, warm_timeout_ms=100.0, warm_retries=1,
                       breaker_failures=3, breaker_reset_s=0.2,
                       launch_retries=2, watchdog_ms=200.0, requeue_limit=1,
                       max_batch=4, seed=seed)
        rng = np.random.default_rng(100 + seed)
        qs = rng.standard_normal((4, ccfg.dim)).astype(np.float32)
        reqs = []
        for i in range(16):
            q = qs[i % 4]
            if i % 5 == 4:
                plan = (db.session(Principal(tenant_id=i % 3,
                                             group_bits=ALL_BITS))
                        .search(q, normalize=False).limit(6).plan())
            else:
                plan = (db.admin_session().search(q, normalize=False)
                        .limit(6).plan())
            reqs.append(ServeRequest(plan=plan, arrival_t=clock(),
                                     req_id=i, tenant=i % 3))
        assert all(sched.offer(r) for r in reqs)
        results = sched.run_until_idle()
        assert len(results) == 16, "every request must resolve exactly once"
        assert plan_f.total_fired() > 0, "the storm must actually fire"
        hot_tenant = np.asarray(db.log.snapshot()["tenant"])
        warm_tenant = np.asarray(db.router.warm.meta["tenant"])
        n_correct = n_degraded = n_failed = 0
        for res in results:
            # isolation holds for EVERY class (vacuous for sentinel slots)
            t = res.request.plan.pred.tenant
            if t != -2:
                m = res.slots >= 0
                owner = np.where(res.tiers == 0,
                                 hot_tenant[res.slots], warm_tenant[res.slots])
                assert (owner[m] == t).all(), "cross-tenant row under faults"
            if res.served == "failed":
                n_failed += 1
                assert (res.slots == -1).all()
            elif res.degraded:
                n_degraded += 1
                assert any("warm-unavailable" in d for d in res.degraded)
            else:
                n_correct += 1
                s, sl, tr = _clean_ref(db, res.request.plan)
                assert (np.array_equal(res.scores, s)
                        and np.array_equal(res.slots, sl)
                        and np.array_equal(res.tiers, tr)), \
                    "undegraded response not bit-identical under faults"
        assert n_correct + n_degraded + n_failed == 16
    finally:
        db.attach_faults(None)
        db.warm_guard = None
