"""Unified query vs numpy oracle; engine equivalence (ref vs pallas)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Predicate, StoreConfig, TransactionLog, empty, unified_query
from repro.data.corpus import CorpusConfig, make_corpus, make_queries


@pytest.fixture(scope="module")
def stack():
    ccfg = CorpusConfig(n_docs=3000, dim=32, n_tenants=6, n_categories=4)
    scfg = StoreConfig(capacity=4096, dim=32)
    log = TransactionLog(scfg, empty(scfg))
    log.ingest(make_corpus(ccfg))
    return log.snapshot(), ccfg


def oracle(snap, q, pred: Predicate, k):
    emb = np.asarray(snap["emb"])
    ten = np.asarray(snap["tenant"])
    ts = np.asarray(snap["updated_at"])
    cat = np.asarray(snap["category"])
    acl = np.asarray(snap["acl"])
    mask = ten >= 0
    if pred.tenant != -2:
        mask &= ten == pred.tenant
    mask &= ts >= pred.min_ts
    mask &= ((1 << cat.astype(np.uint64)) & np.uint64(pred.cat_mask)) != 0
    mask &= (acl & np.uint32(pred.acl_bits)) != 0
    scores = np.asarray(q) @ emb.T
    scores[:, ~mask] = -np.inf
    idx = np.argsort(-scores, axis=1)[:, :k]
    return scores, idx, mask


PREDS = [
    Predicate(),
    Predicate(tenant=2),
    Predicate(min_ts=90 * 86400),
    Predicate(cat_mask=0b0101),
    Predicate(acl_bits=0b0011),
    Predicate(tenant=1, min_ts=60 * 86400, cat_mask=0b0110, acl_bits=0b0101),
]


@pytest.mark.parametrize("pred", PREDS)
@pytest.mark.parametrize("engine", ["ref", "pallas"])
def test_matches_oracle(stack, pred, engine):
    snap, ccfg = stack
    q = make_queries(ccfg, 1, batch=3, seed=9)[0]
    s, slots = unified_query(snap, q, pred, k=7, engine=engine)
    s, slots = np.asarray(s), np.asarray(slots)
    ref_scores, ref_idx, mask = oracle(snap, q, pred, 7)
    for b in range(3):
        got = [x for x in slots[b] if x >= 0]
        # every returned row satisfies the predicate
        for g in got:
            assert mask[g], f"row {g} violates predicate {pred}"
        # score multiset matches the oracle's top-k (ties may permute slots)
        want = sorted(ref_scores[b, ref_idx[b]][np.isfinite(ref_scores[b, ref_idx[b]])],
                      reverse=True)[: len(got)]
        np.testing.assert_allclose(sorted(s[b][s[b] > -1e30], reverse=True),
                                   want, rtol=1e-4, atol=1e-5)


def test_underfill_returns_minus_one(stack):
    snap, ccfg = stack
    q = make_queries(ccfg, 1, batch=1, seed=9)[0]
    # impossible predicate: future min_ts
    pred = Predicate(min_ts=10**9)
    s, slots = unified_query(snap, q, pred, k=5)
    assert (np.asarray(slots) == -1).all()


def test_pred_cache_lru_keeps_hot_entries(monkeypatch):
    from repro.core import query as qmod
    monkeypatch.setattr(qmod, "_PRED_CACHE", type(qmod._PRED_CACHE)())
    monkeypatch.setattr(qmod, "_PRED_CACHE_CAP", 4)
    hot = Predicate(tenant=7)
    hot.as_array()
    for i in range(16):
        Predicate(min_ts=i + 1).as_array()
        hot.as_array()                      # touch the hot entry every time
    # bounded, and the hot predicate survived the churn (LRU, not clear())
    assert len(qmod._PRED_CACHE) <= 4
    assert hot in qmod._PRED_CACHE
    # cached array is reused, not rebuilt
    assert hot.as_array() is qmod._PRED_CACHE[hot]


def test_engines_agree(stack):
    snap, ccfg = stack
    q = make_queries(ccfg, 1, batch=2, seed=4)[0]
    pred = Predicate(tenant=3, min_ts=30 * 86400)
    s1, i1 = unified_query(snap, q, pred, k=9, engine="ref")
    s2, i2 = unified_query(snap, q, pred, k=9, engine="pallas")
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-6)
