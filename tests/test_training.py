"""Training stack: optimizers, schedules, checkpoint/restart, straggler
detection, elastic mesh planning, gradient compression."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.lm_pipeline import Prefetcher, synthetic_lm_batches
from repro.distributed.compression import ef_compress, ef_init
from repro.models.transformer import TransformerConfig, init, loss_fn
from repro.training import checkpoint as ckpt
from repro.training.fault_tolerance import (StragglerDetector, plan_mesh_shape,
                                            resume_or_init)
from repro.training.optimizer import (adafactor, adamw, apply_updates,
                                      cosine_schedule, sgd)
from repro.training.train_loop import (Trainer, TrainerConfig, init_state,
                                       make_train_step)


def _quad(p):
    return jnp.sum(p["w"] ** 2) + jnp.sum(p["m"] ** 2)


def _run_opt(opt, steps=200):
    p = {"w": jnp.asarray([3.0, -2.0]), "m": jnp.ones((4, 6)) * 2}
    s = opt.init(p)
    for t in range(steps):
        g = jax.grad(_quad)(p)
        u, s = opt.update(g, s, p, jnp.int32(t))
        p = apply_updates(p, u)
    return float(_quad(p))


def test_optimizers_descend():
    assert _run_opt(sgd(0.1)) < 1e-4
    assert _run_opt(adamw(0.05, weight_decay=0.0)) < 1e-4
    f = _run_opt(adafactor(lambda t: 0.5 / jnp.sqrt(t.astype(jnp.float32) + 1)), 300)
    assert f < 109.0 / 100


def test_adafactor_memory_factored():
    opt = adafactor(1e-2)
    p = {"w": jnp.zeros((64, 32))}
    s = opt.init(p)
    n_state = sum(x.size for x in jax.tree_util.tree_leaves(s))
    assert n_state == 64 + 32   # vr + vc, not 64*32


def test_cosine_schedule_shape():
    sch = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(sch(jnp.int32(0))) < 2e-4
    assert abs(float(sch(jnp.int32(10))) - 1e-3) < 1e-4
    assert float(sch(jnp.int32(99))) < 2.1e-4


def _tiny_lm():
    cfg = TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                            n_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32")
    return cfg, init(jax.random.PRNGKey(0), cfg)


def test_train_loop_and_restart_replay():
    cfg, params = _tiny_lm()
    opt = adamw(1e-2, weight_decay=0.01)
    step_fn = make_train_step(lambda p, b: loss_fn(p, cfg, b), opt, donate=False)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(TrainerConfig(total_steps=12, ckpt_dir=d, ckpt_every=5,
                                   log_every=50),
                     step_fn, init_state(params, opt),
                     Prefetcher(synthetic_lm_batches(64, 4, 16)),
                     straggler_detector=StragglerDetector(), log_fn=lambda s: None)
        final = tr.run()
        assert tr.history[-1]["loss"] < tr.history[0]["loss"]
        # crash-restart from step 10 replays to identical params
        st, start = resume_or_init(d, lambda: init_state(init(
            jax.random.PRNGKey(0), cfg), opt))
        assert start == 12
        st10 = ckpt.restore(d, 10, init_state(init(jax.random.PRNGKey(0), cfg), opt))
        data = synthetic_lm_batches(64, 4, 16, start_step=10)
        for _ in range(2):
            st10, _ = step_fn(st10, next(data))
        for a, b in zip(jax.tree_util.tree_leaves(final["params"]),
                        jax.tree_util.tree_leaves(st10["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_checkpoint_atomic_and_keep_k():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
        for s in [1, 2, 3, 4]:
            ckpt.save(d, s, tree, keep=2)
        assert ckpt.all_steps(d) == [3, 4]
        back = ckpt.restore(d, 4, tree)
        np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(5))


def test_async_checkpointer():
    with tempfile.TemporaryDirectory() as d:
        ac = ckpt.AsyncCheckpointer(d, keep=3)
        for s in [1, 2]:
            ac.save(s, {"x": jnp.full((4,), s)})
        ac.close()
        assert ckpt.all_steps(d) == [1, 2]
        got = ckpt.restore(d, 2, {"x": jnp.zeros((4,))})
        assert float(got["x"][0]) == 2


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(warmup_steps=5, z_threshold=3.0)
    for i in range(30):
        det.record(i, 0.1 + 0.001 * (i % 3))
    assert not det.events
    assert det.record(30, 1.5)     # 15x slower step
    assert det.events[-1][0] == 30


def test_elastic_mesh_planning():
    assert plan_mesh_shape(512, model_parallel=16) == (32, 16)
    assert plan_mesh_shape(256, model_parallel=16) == (16, 16)
    # lose a host: 248 devices -> mp shrinks to a divisor, dp stays pow2
    dp, mp = plan_mesh_shape(248, model_parallel=16)
    assert dp * mp <= 248 and 248 % mp == 0


def test_ef_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64), dtype=np.float32))}
    ef = ef_init(g)
    # accumulated dequantized grads converge to the true sum (EF property)
    total_q = jnp.zeros((64, 64))
    for _ in range(50):
        q, ef = ef_compress(g, ef)
        total_q = total_q + q["w"]
    want = np.asarray(g["w"]) * 50
    err = np.abs(np.asarray(total_q) - want).max() / np.abs(want).max()
    assert err < 0.01, f"EF residual not carried: {err}"


def test_pipeline_determinism():
    a = list(next(synthetic_lm_batches(64, 2, 8, start_step=5))["tokens"].ravel())
    b = list(next(synthetic_lm_batches(64, 2, 8, start_step=5))["tokens"].ravel())
    assert a == b
