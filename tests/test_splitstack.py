"""Stack A behaviour: correctness with full retries, measurable inconsistency
window, and leakage under the injected app-layer bug."""
import jax
import numpy as np

from repro.core import Predicate, StoreConfig, TransactionLog, empty, unified_query
from repro.core.splitstack import SplitStackClient
from repro.data.corpus import CorpusConfig, make_corpus, make_queries


def _build(bug=0.0, n=2000):
    ccfg = CorpusConfig(n_docs=n, dim=16, n_tenants=4, n_categories=4)
    scfg = StoreConfig(capacity=4096, dim=16)
    log = TransactionLog(scfg, empty(scfg))
    corpus = make_corpus(ccfg)
    log.ingest(corpus)
    split = SplitStackClient(scfg, filter_bug_rate=bug, rng_seed=1)
    split.ingest(corpus)
    return log, split, corpus, ccfg


def test_split_eventually_matches_unified():
    log, split, corpus, ccfg = _build()
    q = make_queries(ccfg, 1, batch=2)[0]
    pred = Predicate(tenant=2, cat_mask=0b0011)
    s_b, i_b = unified_query(log.snapshot(), q, pred, k=5)
    s_a, i_a = split.query(q, pred, k=5)
    assert set(np.asarray(i_b).ravel().tolist()) == set(i_a.ravel().tolist())
    # and the coordination cost is visible
    assert split.stats.round_trips >= 2


def test_split_window_positive_unified_zero():
    log, split, corpus, ccfg = _build()
    rng = np.random.default_rng(0)
    split.write_gap_s = 0.002  # a 2 ms queue delay between the two commits
    ids = [0, 1, 2]
    emb = rng.standard_normal((3, 16), dtype=np.float32)
    split.update(ids, emb, [999] * 3)
    log.update(ids, emb, [999] * 3)
    assert split.stats.inconsistency_windows_s[-1] >= 0.002
    assert log.inconsistency_window_s == 0.0


def test_split_leaks_under_forced_bug():
    log, split, corpus, ccfg = _build(bug=1.0)   # bug always fires
    tenant_of = np.asarray(corpus.tenant)
    q = make_queries(ccfg, 1, batch=1, seed=2)[0]
    pred = Predicate(tenant=0)
    _, slots = split.query(q, pred, k=8)
    got = slots[0][slots[0] >= 0]
    assert (tenant_of[got] != 0).any(), "bugged split stack should leak"
    # unified is immune to the same workload by construction
    _, slots_b = unified_query(log.snapshot(), q, pred, k=8)
    got_b = np.asarray(slots_b)[0]
    got_b = got_b[got_b >= 0]
    assert (tenant_of[got_b] == 0).all()


def test_pushdown_matches_postfilter_without_retries():
    """Predicate pushdown (the warm-tier route) returns the same qualifying
    set as the retry-until-full post-filter path, in ONE round trip."""
    log, split, corpus, ccfg = _build()
    q = make_queries(ccfg, 1, batch=2, seed=4)[0]
    pred = Predicate(tenant=1, cat_mask=0b0110)
    s_post, i_post = split.query(q, pred, k=5)
    rt0, retry0 = split.stats.round_trips, split.stats.retries
    s_push, i_push = split.query(q, pred, k=5, pushdown=True)
    assert split.stats.round_trips == rt0 + 1
    assert split.stats.retries == retry0
    for b in range(2):
        assert set(i_push[b][i_push[b] >= 0].tolist()) == \
            set(i_post[b][i_post[b] >= 0].tolist())
    # and it agrees with the unified engine's masked scan
    s_u, i_u = unified_query(log.snapshot(), q, pred, k=5)
    assert set(np.asarray(i_u).ravel().tolist()) == \
        set(i_push.ravel().tolist())


def test_pushdown_immune_to_app_layer_filter_bug():
    """The injected tenant-filter bug lives in the app-layer post-filter;
    pushdown evaluates the predicate inside the scan, out of its reach —
    the warm tier inherits the unified engine's isolation construction."""
    log, split, corpus, ccfg = _build(bug=1.0)
    tenant_of = np.asarray(corpus.tenant)
    q = make_queries(ccfg, 1, batch=1, seed=2)[0]
    _, slots = split.query(q, Predicate(tenant=0), k=8, pushdown=True)
    got = slots[0][slots[0] >= 0]
    assert len(got) > 0 and (tenant_of[got] == 0).all()


def test_cache_staleness_bounded_by_invalidation():
    log, split, corpus, ccfg = _build()
    rng = np.random.default_rng(3)
    q = make_queries(ccfg, 1, batch=1)[0]
    split.query(q, Predicate(), k=5)          # warm the cache
    hits_before = split.cache.hits
    # writes invalidate affected cache entries
    split.update([int(corpus.doc_id[0])], rng.standard_normal((1, 16), dtype=np.float32), [5])
    assert 0 not in split.cache._entries or split.cache.get(0) is None
