"""End-to-end RAG serving driver (the paper's kind of system is a serving
stack, so this is the primary end-to-end example): a small LM answers
batched requests grounded in a multi-tenant corpus through the unified data
layer — retrieval, prefill, decode, with per-request provenance.

  PYTHONPATH=src python examples/rag_serve.py [--requests 8] [--tokens 12]
"""
import argparse
import time

import jax
import numpy as np

from repro.api import RagDB
from repro.core import Principal, StoreConfig
from repro.data.corpus import DAY_S, CorpusConfig, make_corpus
from repro.models.transformer import TransformerConfig, init
from repro.serving.engine import RAGEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=12)
    ap.add_argument("--docs", type=int, default=10_000)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    ccfg = CorpusConfig(n_docs=args.docs, dim=48, n_tenants=6, n_categories=5)
    scfg = StoreConfig(capacity=1 << 14, dim=48)
    db = RagDB(scfg)
    corpus = make_corpus(ccfg)
    db.ingest(corpus)

    # a small generator (the paper's contribution is the data layer; the LM
    # just has to be a real decoder with a KV cache)
    cfg = TransformerConfig(name="gen-25m", n_layers=4, d_model=256, n_heads=8,
                            n_kv_heads=4, d_ff=688, vocab_size=2048,
                            dtype="float32", attn_impl="naive")
    params = init(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"generator: {n_params/1e6:.1f}M params; corpus: {args.docs} docs, "
          f"{ccfg.n_tenants} tenants")

    # the engine holds the front door, not a raw snapshot: requests lower to
    # session plans and the batch runs predicate-group batched
    engine = RAGEngine(db, cfg, params, k=4, max_prompt=48,
                       max_len=48 + args.tokens + 2)

    reqs = []
    for i in range(args.requests):
        t = int(rng.integers(0, ccfg.n_tenants))
        reqs.append(Request(
            principal=Principal(tenant_id=t, group_bits=0xFFFFFFFF),
            query_emb=rng.standard_normal(ccfg.dim).astype(np.float32),
            prompt_tokens=rng.integers(1, 2048, 6).astype(np.int32),
            min_ts=ccfg.now_ts - 120 * DAY_S,
            max_new_tokens=args.tokens))

    t0 = time.perf_counter()
    resps = engine.serve(reqs)
    dt = time.perf_counter() - t0
    tenant_of = np.asarray(corpus.tenant)
    print(f"\nserved {len(reqs)} requests in {dt:.2f}s "
          f"({len(reqs)*args.tokens/dt:.1f} tok/s aggregate); retrieval used "
          f"{engine.last_retrieval_device_calls} device calls for "
          f"{len(reqs)} requests (predicate-group batching)")
    for i, r in enumerate(resps[:4]):
        got = r.doc_slots[r.doc_slots >= 0]
        print(f"req{i} tenant={reqs[i].principal.tenant_id} "
              f"docs={got.tolist()} (tenants {tenant_of[got].tolist()}) "
              f"retrieval {r.retrieval_ms:.1f}ms prefill {r.prefill_ms:.0f}ms "
              f"decode {r.decode_ms:.0f}ms -> tokens {r.tokens.tolist()}")
        assert (tenant_of[got] == reqs[i].principal.tenant_id).all()
    print("\nprovenance check: every retrieved doc belongs to its caller's "
          "tenant (engine-level RLS)")


if __name__ == "__main__":
    main()
