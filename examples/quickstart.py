"""Quickstart: the paper in 80 lines.

Builds a multi-tenant corpus, ingests it into BOTH stacks, then shows the
three failure modes of the split stack and their absence in the unified one:
latency under constraints, the inconsistency window, and tenant leakage.

The unified stack is driven through its front door — `RagDB` sessions with a
composable query builder that compiles to an explainable physical plan — so
this is also the 10-line tour of the API.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.api import RagDB
from repro.core import Principal, StoreConfig
from repro.core.splitstack import SplitStackClient
from repro.data.corpus import DAY_S, CorpusConfig, make_corpus, make_queries

ccfg = CorpusConfig(n_docs=20_000, dim=64, n_tenants=8, n_categories=5)
scfg = StoreConfig(capacity=1 << 15, dim=64)
corpus = make_corpus(ccfg)

print("== ingest into both stacks ==")
db = RagDB(scfg)
db.ingest(corpus)
split = SplitStackClient(scfg, filter_bug_rate=1.0)  # bug always fires (demo)
split.ingest(corpus)
print(f"unified: {int(db.log.snapshot()['n_live'])} docs, "
      f"commit_ts={int(db.log.snapshot()['commit_ts'])}")

print("\n== the unified query: similarity + freshness + category + RLS ==")
q = make_queries(ccfg, 1, batch=1)[0]
session = db.session(Principal(tenant_id=3, group_bits=0b0011))
builder = (session.search(np.asarray(q)[0], normalize=False)
           .newer_than(ccfg.now_ts - 60 * DAY_S)
           .in_categories([1, 2])
           .limit(5))
print(builder.explain())
t0 = time.perf_counter()
res = builder.run()
t_unified = time.perf_counter() - t0
slots = res.slots[0]
tenant_of = np.asarray(corpus.tenant)
print(f"top-5 slots {slots.tolist()}  tenants {tenant_of[slots[slots>=0]].tolist()} "
      f" ({t_unified*1e3:.1f} ms, one device program)")

print("\n== the same query on the split stack ==")
pred = builder.lower().predicate()      # identical clause set, old entrance
t0 = time.perf_counter()
_, slots_a = split.query(q, pred, k=5)
t_split = time.perf_counter() - t0
got = slots_a[0][slots_a[0] >= 0]
leaked = (tenant_of[got] != session.principal.tenant_id).sum()
print(f"round trips: {split.stats.round_trips}, retries: {split.stats.retries} "
      f"({t_split*1e3:.1f} ms)")
print(f"LEAKED {leaked}/{len(got)} docs from other tenants "
      f"(app-layer tenant filter bug active)")
print("unified leaked 0 by construction — the predicate runs inside the kernel")

print("\n== freshness: atomic vs two-phase writes ==")
rng = np.random.default_rng(0)
new_emb = rng.standard_normal((4, 64), dtype=np.float32)
db.update([0, 1, 2, 3], jnp.asarray(new_emb), [ccfg.now_ts] * 4)
split.write_gap_s = 0.003
split.update([0, 1, 2, 3], new_emb, [ccfg.now_ts] * 4)
print(f"unified inconsistency window: {db.log.inconsistency_window_s*1e3:.2f} ms "
      f"(embedding+metadata commit in ONE program)")
print(f"split inconsistency window:   "
      f"{split.stats.inconsistency_windows_s[-1]*1e3:.2f} ms "
      f"(reader sees new vector + stale metadata in the gap)")
