"""Training driver: train a small LM on the synthetic next-token stream with
the full production loop — sharded (if >1 device), checkpointed, straggler-
monitored, crash-restartable.

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --steps 400   # resumes at 200
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro.data.lm_pipeline import Prefetcher, synthetic_lm_batches
from repro.models.transformer import TransformerConfig, init, loss_fn
from repro.training.fault_tolerance import StragglerDetector, resume_or_init
from repro.training.optimizer import adamw, cosine_schedule
from repro.training.train_loop import (Trainer, TrainerConfig, init_state,
                                       make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~10M params — sized so a few hundred CPU steps visibly learn the
    # synthetic Markov stream; the same loop drives the pod-scale configs
    cfg = TransformerConfig(name="lm-10m", n_layers=4, d_model=256, n_heads=8,
                            n_kv_heads=4, d_ff=688, vocab_size=512,
                            dtype="float32", attn_impl="naive")
    opt = adamw(cosine_schedule(3e-3, warmup=20, total=args.steps),
                weight_decay=0.01)

    def fresh():
        params = init(jax.random.PRNGKey(0), cfg)
        n = sum(x.size for x in jax.tree_util.tree_leaves(params))
        print(f"init {n/1e6:.1f}M params")
        return init_state(params, opt)

    state, start = resume_or_init(args.ckpt, fresh)
    if start:
        print(f"resumed from checkpoint at step {start}")

    step_fn = make_train_step(lambda p, b: loss_fn(p, cfg, b), opt, donate=False)
    data = Prefetcher(synthetic_lm_batches(cfg.vocab_size, args.batch, args.seq,
                                           start_step=start))
    det = StragglerDetector()
    trainer = Trainer(TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                                    ckpt_every=50, log_every=10),
                      step_fn, state, data, straggler_detector=det)
    trainer.run()
    if det.events:
        print(f"straggler events: {[(s, f'{t:.2f}s') for s, t, _ in det.events]}")
    first, last = trainer.history[0]["loss"], trainer.history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps - start} steps "
          f"(mean step {det.mean_step_s*1e3:.0f} ms)")


if __name__ == "__main__":
    main()
